"""Serving observability: per-tenant counters + latency/occupancy/lag
histograms, dumped as JSON for the bench gate.

Everything here is host-side and lock-cheap: counters are plain ints
behind one lock, histograms are bounded reservoirs (the newest
``Histogram.cap`` samples) with percentiles computed on demand — the
recording path a query touches is two appends, never a sort.  The JSON
schema (``Metrics.to_json``) is the contract the serving benchmark rows
and the ``--smoke`` output are built from::

    {
      "tenants": {
        "<name>": {
          "counters": {"submitted": .., "completed": .., "rejected": ..,
                       "shed": .., "batches": .., "rebuilds": ..,
                       "moves": ..},
          "query_latency_us": {"count", "p50", "p99", "max", "mean"},
          "batch_occupancy":  {...},     # filled slots / max_batch
          "rebuild_lag_versions": {...}, # staleness at response time
          "rebuild_duration_us": {...},
          "gauges": {"snapshot_version": .., "snapshot_regions": ..,
                     "snapshot_bytes": ..}  # last published snapshot
        }
      }
    }

Gauges are last-write-wins scalars (the rebuild worker sets them at
every snapshot publish) — the memory-accounting companion to the CSR
emit route: ``snapshot_bytes`` is the device+host footprint of the
tenant's current ``DDMSnapshot``, so a fleet dashboard can watch
serving memory the same way ``emit_route_bytes`` models kernel VMEM.
"""
from __future__ import annotations

import json
import threading

import numpy as np

SUMMARY_FIELDS = ("count", "p50", "p99", "max", "mean")


class Histogram:
    """Bounded-reservoir histogram: keeps the newest ``cap`` samples
    (steady-state behavior is what the percentiles should reflect) plus
    an all-time count."""

    def __init__(self, cap: int = 65536):
        self.cap = cap
        self._vals: list[float] = []
        self._seen = 0

    def record(self, value: float) -> None:
        self._seen += 1
        self._vals.append(float(value))
        if len(self._vals) > self.cap:
            del self._vals[: len(self._vals) - self.cap]

    def summary(self) -> dict:
        if not self._vals:
            return {k: 0 for k in SUMMARY_FIELDS}
        a = np.asarray(self._vals, np.float64)
        return {
            "count": self._seen,
            "p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)),
            "max": float(a.max()),
            "mean": float(a.mean()),
        }


COUNTERS = ("submitted", "completed", "rejected", "shed", "batches",
            "rebuilds", "moves")


class TenantMetrics:
    """One tenant's counters + histograms (guarded by the parent lock)."""

    def __init__(self):
        self.counters = {name: 0 for name in COUNTERS}
        self.gauges: dict[str, float] = {}
        self.query_latency_us = Histogram()
        self.batch_occupancy = Histogram()
        self.rebuild_lag_versions = Histogram()
        self.rebuild_duration_us = Histogram()

    def to_dict(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "query_latency_us": self.query_latency_us.summary(),
            "batch_occupancy": self.batch_occupancy.summary(),
            "rebuild_lag_versions": self.rebuild_lag_versions.summary(),
            "rebuild_duration_us": self.rebuild_duration_us.summary(),
        }


class Metrics:
    """Server-wide registry: one ``TenantMetrics`` per tenant name."""

    def __init__(self):
        self._lock = threading.Lock()
        self._tenants: dict[str, TenantMetrics] = {}

    def tenant(self, name: str) -> TenantMetrics:
        with self._lock:
            tm = self._tenants.get(name)
            if tm is None:
                tm = self._tenants[name] = TenantMetrics()
            return tm

    def bump(self, tenant: str, counter: str, by: int = 1) -> None:
        tm = self.tenant(tenant)
        with self._lock:
            tm.counters[counter] += by

    def set_gauge(self, tenant: str, gauge: str, value: float) -> None:
        """Last-write-wins scalar (snapshot version / regions / bytes)."""
        tm = self.tenant(tenant)
        with self._lock:
            tm.gauges[gauge] = value

    def to_dict(self) -> dict:
        with self._lock:
            return {"tenants": {name: tm.to_dict()
                                for name, tm in self._tenants.items()}}

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)
