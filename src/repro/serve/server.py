"""``DDMServer`` — the multi-tenant async DDM serving layer.

The paper frames DDM as a *service*: the HLA runtime continuously
reports subscription/update intersections while regions churn.  This
module is that serving shape on top of the engine:

* **Tenancy** — per-tenant namespaces (``add_tenant``), each with its
  own region store, bounded queues, and one memoized ``MatchPlan`` per
  ``(tenant, MatchSpec)`` via the engine's plan-cache ``key`` hook.
  Capacity autoscaling rides the plan's ``grow`` policy: per-tenant
  query capacities double-and-memoize independently.
* **Batching + admission** — ``submit`` enqueues a box query and
  returns a future; the dispatcher coalesces queued requests into
  sentinel-padded ``MatchPlan.query`` calls (static shapes — zero
  steady-state retraces) under a max-batch/max-delay policy with
  round-robin fairness across tenants and bounded queue depth with
  explicit shed/reject semantics (``serve.admission``).
* **Double-buffered rebuilds** — ``update_regions`` churn never blocks
  readers: writers mutate the store and mark a rebuild pending; the
  rebuild worker captures the store (O(n) copy under the tenant lock),
  builds interval trees off-lock into a shadow snapshot, and publishes
  it with one atomic reference swap.  Every response carries the
  snapshot ``version`` and a ``staleness`` bound (store version minus
  snapshot version at launch).
* **Observability** — per-tenant counters, latency/occupancy/lag
  histograms (``serve.metrics``), dumped as JSON for the bench gate.

Two drive modes: ``start()``/``stop()`` run a dispatcher thread and a
rebuild thread (the async production shape); ``pump()`` drives both
paths synchronously on the caller's thread (deterministic tests, and
the ``--smoke`` harness).
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import Future

import numpy as np

from ..core.engine import MatchSpec
from ..core.regions import Regions
from .admission import AdmissionError, AdmissionPolicy
from .batching import (BatchPolicy, QueryRequest, QueryResult, TARGETS,
                       execute_batch)
from .metrics import Metrics
from .tenancy import Tenant

__all__ = ["DDMServer", "AdmissionError", "AdmissionPolicy", "BatchPolicy",
           "QueryResult"]

_SERVER_IDS = itertools.count()


class DDMServer:
    """Multi-tenant DDM serving front end (see module docstring)."""

    def __init__(self, *, batch: BatchPolicy | None = None,
                 admission: AdmissionPolicy | None = None,
                 compilation_cache: bool | str = False):
        self.batch_policy = batch or BatchPolicy()
        self.admission_policy = admission or AdmissionPolicy()
        self.metrics = Metrics()
        self._server_id = next(_SERVER_IDS)
        self._tenants: dict[str, Tenant] = {}
        self._order: list[str] = []
        self._cursor = 0
        self._cond = threading.Condition()
        self._stop = False
        self._threads: list[threading.Thread] = []
        # test/ops injection point: fn(phase, tenant_name) called by the
        # rebuild path at "capture" (store copied, shadow build starting)
        # and "publish" (snapshot swapped in)
        self.rebuild_hook = None
        if compilation_cache:
            from . import compile_cache
            compile_cache.enable(None if compilation_cache is True
                                 else compilation_cache)

    # -- tenancy -------------------------------------------------------------
    def add_tenant(self, name: str, S: Regions, U: Regions, *,
                   spec: MatchSpec | None = None,
                   cap_hint: int = 64) -> Tenant:
        """Register a namespace with its own regions, plan, and queues."""
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        t = Tenant(name, S, U, spec=spec, cap_hint=cap_hint,
                   admission=self.admission_policy,
                   plan_key=("serve", self._server_id, name))
        with self._cond:
            self._tenants[name] = t
            self._order.append(name)
        self.metrics.tenant(name)
        self._record_snapshot_gauges(name, t.live)
        return t

    def _record_snapshot_gauges(self, name: str, snap) -> None:
        """Memory/version accounting for the tenant's live snapshot."""
        self.metrics.set_gauge(name, "snapshot_version", snap.version)
        self.metrics.set_gauge(name, "snapshot_regions",
                               snap.S.n + snap.U.n)
        self.metrics.set_gauge(name, "snapshot_bytes", snap.nbytes)

    def tenant(self, name: str) -> Tenant:
        t = self._tenants.get(name)
        if t is None:
            raise ValueError(
                f"unknown tenant {name!r}; registered: "
                f"{sorted(self._tenants)}")
        return t

    # -- read path -----------------------------------------------------------
    def submit(self, tenant: str, target: str, lo, hi) -> Future:
        """Enqueue one box query; the future resolves to a
        ``QueryResult`` (or raises ``AdmissionError`` if shed)."""
        if target not in TARGETS:
            raise ValueError(f"target must be one of {TARGETS}, "
                             f"got {target!r}")
        t = self.tenant(tenant)
        d = t.svc.d
        req = QueryRequest(
            tenant=tenant, target=target,
            lo=np.asarray(lo, np.float32).reshape(d),
            hi=np.asarray(hi, np.float32).reshape(d),
            future=Future(), t_submit=time.perf_counter())
        try:
            evicted = t.queues[target].offer(req)
        except AdmissionError:
            self.metrics.bump(tenant, "rejected")
            raise
        if evicted is not None:
            self.metrics.bump(tenant, "shed")
            evicted.future.set_exception(AdmissionError(
                tenant, "evicted by drop_oldest shed",
                self.admission_policy.max_queue,
                self.admission_policy.max_queue))
        self.metrics.bump(tenant, "submitted")
        with self._cond:
            self._cond.notify_all()
        return req.future

    def query(self, tenant: str, target: str, lo, hi,
              timeout: float = 30.0) -> QueryResult:
        """Submit + wait.  With no dispatcher thread running, drives one
        synchronous ``pump`` so single-threaded callers just work."""
        fut = self.submit(tenant, target, lo, hi)
        if not self._threads:
            self.pump(rebuilds=False)
        return fut.result(timeout=timeout)

    # -- write path ----------------------------------------------------------
    def update_regions(self, tenant: str, kind: str, idx, new_lo,
                       new_hi) -> int:
        """Apply one churn batch to a tenant's store (validated,
        last-write-wins) and schedule a shadow rebuild.  Readers keep
        answering from the published snapshot — this call never blocks
        them, and never waits for the rebuild itself."""
        t = self.tenant(tenant)
        moved = t.apply_moves(kind, idx, new_lo, new_hi)
        if moved:
            self.metrics.bump(tenant, "moves", by=moved)
            with self._cond:
                self._cond.notify_all()
        return moved

    # -- dispatch internals --------------------------------------------------
    def _rr_order(self) -> list[str]:
        """Round-robin rotation: each call starts one tenant later, so
        no tenant is permanently first in line for batch slots."""
        with self._cond:
            order = list(self._order)
            if not order:
                return order
            start = self._cursor % len(order)
            self._cursor += 1
        return order[start:] + order[:start]

    def _launch(self, t: Tenant, target: str,
                reqs: list[QueryRequest]) -> None:
        snap = t.live                       # atomic reference read
        results = execute_batch(t.svc, snap, target, reqs,
                                self.batch_policy.max_batch,
                                t.store_version)
        tm = self.metrics.tenant(t.name)
        self.metrics.bump(t.name, "completed", by=len(reqs))
        self.metrics.bump(t.name, "batches")
        tm.batch_occupancy.record(len(reqs) / self.batch_policy.max_batch)
        for r in results:
            tm.query_latency_us.record(r.latency_s * 1e6)
        tm.rebuild_lag_versions.record(results[0].staleness if results
                                       else 0)

    def _dispatch_once(self, force: bool) -> int:
        """One fairness round over every (tenant, target) stream.

        ``force`` launches any non-empty queue (the pump path);
        otherwise a stream launches only when full or when its oldest
        request has aged past ``max_delay_s``.  Returns requests served.
        """
        served = 0
        now = time.perf_counter()
        pol = self.batch_policy
        for name in self._rr_order():
            t = self._tenants[name]
            for target in TARGETS:
                q = t.queues[target]
                depth = len(q)
                if depth == 0:
                    continue
                if not force and depth < pol.max_batch:
                    oldest = q.oldest_submit_time()
                    if oldest is None or now - oldest < pol.max_delay_s:
                        continue
                reqs = q.take(pol.max_batch)
                if reqs:
                    self._launch(t, target, reqs)
                    served += len(reqs)
        return served

    def _rebuild_once(self) -> bool:
        """Rebuild + publish at most one tenant's shadow snapshot."""
        for name in self._rr_order():
            t = self._tenants[name]
            view = t.capture_for_rebuild()
            if view is None:
                continue
            if self.rebuild_hook is not None:
                self.rebuild_hook("capture", name)
            t0 = time.perf_counter()
            snap = view.build()             # off-lock: readers unblocked
            dt = time.perf_counter() - t0
            t.publish(snap)
            if self.rebuild_hook is not None:
                self.rebuild_hook("publish", name)
            tm = self.metrics.tenant(name)
            self.metrics.bump(name, "rebuilds")
            tm.rebuild_duration_us.record(dt * 1e6)
            self._record_snapshot_gauges(name, snap)
            return True
        return False

    # -- synchronous drive (deterministic tests, smoke harness) --------------
    def pump(self, *, queries: bool = True, rebuilds: bool = True) -> int:
        """Drive the serving loops on the caller's thread until idle:
        drain every queue (forced launches), then run every pending
        rebuild.  Returns the number of requests served."""
        served = 0
        if queries:
            while True:
                n = self._dispatch_once(force=True)
                served += n
                if n == 0:
                    break
        if rebuilds:
            while self._rebuild_once():
                pass
        return served

    # -- async drive ---------------------------------------------------------
    def start(self) -> None:
        """Spawn the dispatcher and rebuild-worker threads."""
        if self._threads:
            return
        self._stop = False
        for fn, tag in ((self._dispatch_loop, "dispatch"),
                        (self._rebuild_loop, "rebuild")):
            th = threading.Thread(target=fn, name=f"ddm-serve-{tag}",
                                  daemon=True)
            th.start()
            self._threads.append(th)

    def stop(self, drain: bool = True) -> None:
        """Stop the worker threads; ``drain`` serves whatever is queued
        (and finishes pending rebuilds) before returning."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        for th in self._threads:
            th.join(timeout=30.0)
        self._threads = []
        if drain:
            self.pump()

    def _wait_tick(self) -> bool:
        """Sleep until new work may exist; False when stopping."""
        timeout = min(max(self.batch_policy.max_delay_s / 2, 5e-4), 0.05)
        with self._cond:
            if self._stop:
                return False
            self._cond.wait(timeout=timeout)
            return not self._stop

    def _dispatch_loop(self) -> None:
        while self._wait_tick():
            self._dispatch_once(force=False)
        self._dispatch_once(force=True)     # final drain on stop

    def _rebuild_loop(self) -> None:
        while self._wait_tick():
            while self._rebuild_once():
                pass
        while self._rebuild_once():
            pass

    # -- observability -------------------------------------------------------
    def metrics_dict(self) -> dict:
        return self.metrics.to_dict()

    def metrics_json(self, indent: int = 2) -> str:
        return self.metrics.to_json(indent=indent)
