"""Admission control: bounded per-tenant queues with explicit shed
semantics.

A production matcher under churn cannot let a slow tenant grow an
unbounded backlog — memory and tail latency both blow up.  Each tenant
gets one bounded FIFO; when it is full the ``shed`` policy decides what
gives:

``reject``       refuse the *new* request (``AdmissionError`` raised at
                 ``submit`` time) — callers get backpressure immediately.
``drop_oldest``  evict the oldest queued request (its future fails with
                 ``AdmissionError``) and admit the new one — freshest
                 work wins, the paper's DDS-style "latest sample"
                 semantics for interactive simulation.

Both paths are *explicit*: a shed request is never silently lost — it
is counted (``rejected``/``shed``) and its future carries the error.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque

SHED_POLICIES = ("reject", "drop_oldest")


class AdmissionError(RuntimeError):
    """A request was refused or evicted by admission control."""

    def __init__(self, tenant: str, reason: str, depth: int, bound: int):
        self.tenant = tenant
        self.reason = reason
        self.depth = depth
        self.bound = bound
        super().__init__(
            f"tenant {tenant!r}: {reason} (queue depth {depth} at "
            f"bound {bound})")


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Knobs for one server's admission control."""

    max_queue: int = 1024     # per-tenant pending-request bound
    shed: str = "reject"      # what gives when the queue is full

    def __post_init__(self):
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.shed not in SHED_POLICIES:
            raise ValueError(
                f"shed must be one of {SHED_POLICIES}, got {self.shed!r}")


class TenantQueue:
    """One tenant's bounded FIFO of pending requests.

    ``offer`` enforces the admission policy; ``take`` hands up to
    ``limit`` requests to the batcher.  All methods are thread-safe
    under the queue's own lock; the server's condition variable handles
    cross-thread wakeups.
    """

    def __init__(self, tenant: str, policy: AdmissionPolicy):
        self.tenant = tenant
        self.policy = policy
        self._lock = threading.Lock()
        self._q: deque = deque()

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    def offer(self, request):
        """Admit ``request`` or apply the shed policy.

        Returns the evicted request under ``drop_oldest`` (the caller
        fails its future), ``None`` when nothing was shed.  Raises
        ``AdmissionError`` under ``reject`` when full.
        """
        with self._lock:
            if len(self._q) < self.policy.max_queue:
                self._q.append(request)
                return None
            if self.policy.shed == "reject":
                raise AdmissionError(self.tenant, "queue full, rejecting",
                                     len(self._q), self.policy.max_queue)
            evicted = self._q.popleft()
            self._q.append(request)
            return evicted

    def take(self, limit: int) -> list:
        """Pop up to ``limit`` requests FIFO (the batcher's drain)."""
        out = []
        with self._lock:
            while self._q and len(out) < limit:
                out.append(self._q.popleft())
        return out

    def oldest_submit_time(self):
        """Submit timestamp of the head request (None when empty) —
        drives the batcher's max-delay coalescing decision."""
        with self._lock:
            return self._q[0].t_submit if self._q else None
