"""``python -m repro.serve`` — run the self-checking serving harness.

``--smoke`` is the CI mode: a few ticks of multi-tenant churn at small
scale, every answer checked set-identical to a brute-force oracle for
the snapshot version it was served from, zero steady-state retraces
enforced via ``analysis.retrace.no_retrace``, per-tenant metrics dumped
as JSON, and ``SERVE_SMOKE_OK`` printed on success (exit 0).

``--threaded`` runs the same harness through the async dispatcher and
rebuild-worker threads instead of the synchronous ``pump`` drive.
Larger sweeps: raise ``--n/--ticks/--moves`` (the full-scale churn
trajectory lives in ``benchmarks/ddm_dynamic.py``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.serve")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small-scale churn + parity + "
                         "zero-retrace checks")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--n", type=int, default=2048,
                    help="regions per tenant (n_total)")
    ap.add_argument("--ticks", type=int, default=6)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--moves", type=int, default=64,
                    help="region moves per tick per tenant")
    ap.add_argument("--queries", type=int, default=48,
                    help="queries per burst per tenant")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--threaded", action="store_true",
                    help="drive through the async worker threads")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-compile-cache", action="store_true",
                    help="skip enabling the persistent compilation cache")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the metrics/stats record to PATH")
    args = ap.parse_args(argv)

    from .harness import run_churn

    cache = None if args.no_compile_cache else True
    t0 = time.time()
    stats = run_churn(
        tenants=args.tenants, n_total=args.n, ticks=args.ticks,
        warmup=args.warmup, moves_per_tick=args.moves,
        queries_per_tick=args.queries, max_batch=args.max_batch,
        seed=args.seed, threaded=args.threaded,
        compilation_cache=cache,
        progress=lambda msg: print(f"# {msg}", flush=True))
    wall = time.time() - t0

    record = {
        "params": {k: getattr(args, k.replace("-", "_"))
                   for k in ("tenants", "n", "ticks", "warmup", "moves",
                             "queries", "threaded")},
        "wall_s": round(wall, 3),
        "p50_query_us": round(stats["p50_query_s"] * 1e6, 1),
        "p99_query_us": round(stats["p99_query_s"] * 1e6, 1),
        "p99_stale_query_us": round(stats["p99_stale_query_s"] * 1e6, 1),
        "rebuild_p50_us": round(stats["rebuild_p50_s"] * 1e6, 1),
        "rebuild_p99_us": round(stats["rebuild_p99_s"] * 1e6, 1),
        "parity_checks": stats["parity_checks"],
        "metrics": stats["metrics"],
    }
    print(json.dumps(record, indent=2, sort_keys=True))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(record, fh, indent=2, sort_keys=True)
            fh.write("\n")
    assert stats["parity_checks"] > 0, "oracle parity never exercised"
    print("SERVE_SMOKE_OK" if args.smoke else "SERVE_OK", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
