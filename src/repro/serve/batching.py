"""Request batching: queued point/region queries coalesced into batched
``MatchPlan.query`` calls.

The engine's query path is batched and retrace-free only at *stable
shapes*: ``plan.query`` jits per batch size, so a naive "batch whatever
is queued" policy retraces on every distinct queue depth.  The batcher
therefore pads every device call to exactly ``BatchPolicy.max_batch``
rows with sentinel boxes (``lo=+inf, hi=-inf`` — the tree walk prunes
them at the root, so padding costs one lane each, no retrace ever).

Coalescing policy: a batch launches when it is full (``max_batch``
requests of one (tenant, target) stream) or when the oldest queued
request has waited ``max_delay_s`` — the classic max-batch/max-delay
trade between throughput and tail latency.  Batch occupancy
(filled/max_batch) is recorded per launch so the trade is observable.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future

import numpy as np

TARGETS = ("sub", "upd")


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Knobs for the coalescing loop."""

    max_batch: int = 256      # device-call batch rows (also the pad size)
    max_delay_s: float = 2e-3  # oldest-request age that forces a launch

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_delay_s < 0:
            raise ValueError(
                f"max_delay_s must be >= 0, got {self.max_delay_s}")


@dataclasses.dataclass
class QueryRequest:
    """One queued box query against a tenant's ``target`` region set."""

    tenant: str
    target: str               # "sub" | "upd" — the set being searched
    lo: np.ndarray            # (d,)
    hi: np.ndarray            # (d,)
    future: Future
    t_submit: float


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """What a completed query future resolves to."""

    ids: np.ndarray           # (k,) int32 region ids, overlap-verified
    version: int              # snapshot version the answer was read from
    staleness: int            # store_version - snapshot version at launch
    latency_s: float          # submit → resolution wall time

    def id_set(self) -> set[int]:
        return set(self.ids.astype(int).tolist())


def pad_boxes(reqs: list[QueryRequest], d: int,
              max_batch: int) -> tuple[np.ndarray, np.ndarray]:
    """(max_batch, d) query boxes, sentinel-padded to a static shape.

    The sentinel (``lo=+inf, hi=-inf``) makes the interval-tree root
    prune immediately (``maxupper <= q_lo``), so pad rows return zero
    hits without a dedicated masking path.
    """
    lo = np.full((max_batch, d), np.inf, np.float32)
    hi = np.full((max_batch, d), -np.inf, np.float32)
    for i, r in enumerate(reqs):
        lo[i] = r.lo
        hi[i] = r.hi
    return lo, hi


def execute_batch(svc, snap, target: str, reqs: list[QueryRequest],
                  max_batch: int,
                  store_version: int) -> list[QueryResult]:
    """Run one coalesced ``plan.query`` call and resolve every future.

    All answers come from ``snap`` (an immutable ``DDMSnapshot``) — the
    store may be mid-churn, which is exactly why the response carries
    ``version`` and ``staleness`` instead of pretending to be current.
    Returns the results (in request order) for metrics recording.
    """
    d = snap.s_lo.shape[1]
    q_lo, q_hi = pad_boxes(reqs, d, max_batch)
    ids, _ = svc.query_snapshot(snap, target, q_lo, q_hi)
    ids = np.asarray(ids)
    t_done = time.perf_counter()
    staleness = store_version - snap.version
    results = []
    for i, r in enumerate(reqs):
        row = ids[i]
        res = QueryResult(
            ids=row[row >= 0].astype(np.int32),
            version=snap.version,
            staleness=staleness,
            latency_s=t_done - r.t_submit)
        results.append(res)
        r.future.set_result(res)
    return results
