"""Persistent JAX compilation cache for the serving layer.

First-compile latency is the serving layer's cold-start cost: every
fresh process pays seconds of XLA compilation before the first query is
answered, even though the computations are byte-identical across
restarts.  Enabling ``jax_compilation_cache_dir`` persists compiled
executables to disk, turning restart into a warm start — ROADMAP's
"compile time as a first-class perf axis" slice.  CI jobs point
``JAX_COMPILATION_CACHE_DIR`` at a cached directory for the same
reason; the serving benchmark records first-compile vs warm-start rows
(``gate:false`` — absolute compile times are runner-dependent).
"""
from __future__ import annotations

import os
from pathlib import Path

import jax

DEFAULT_DIR = os.path.join(
    os.environ.get("XDG_CACHE_HOME", os.path.expanduser("~/.cache")),
    "repro-jax-cache")

_enabled_dir: str | None = None


def enable(cache_dir: str | None = None, *,
           min_compile_time_secs: float = 0.0) -> str | None:
    """Enable the persistent compilation cache (idempotent).

    Directory precedence: explicit argument, ``$JAX_COMPILATION_CACHE_DIR``,
    then a per-user default.  ``min_compile_time_secs=0`` caches every
    executable — serving-scale query kernels compile fast but often, so
    the default 1 s threshold would skip exactly the entries a restart
    wants.  Returns the directory in effect, or ``None`` when this JAX
    build exposes no compilation-cache config (the feature degrades to
    a no-op rather than failing the server).
    """
    global _enabled_dir
    cache_dir = (cache_dir
                 or os.environ.get("JAX_COMPILATION_CACHE_DIR")
                 or DEFAULT_DIR)
    if _enabled_dir == cache_dir:
        return _enabled_dir
    try:
        Path(cache_dir).mkdir(parents=True, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
    except (AttributeError, OSError):
        return None
    for opt, val in (
            ("jax_persistent_cache_min_compile_time_secs",
             min_compile_time_secs),
            ("jax_persistent_cache_min_entry_size_bytes", 0)):
        try:
            jax.config.update(opt, val)
        except AttributeError:
            pass   # older JAX: the dir alone still enables the cache
    _enabled_dir = cache_dir
    return _enabled_dir


def enabled_dir() -> str | None:
    """The directory the cache was enabled with (None = not enabled)."""
    return _enabled_dir
