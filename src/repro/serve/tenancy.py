"""Per-tenant namespaces: region store, plan, queues, and the double
buffer.

A tenant owns one ``DDMService`` (the authoritative region store +
validation + version counter), one memoized ``MatchPlan`` keyed
``(server_id, tenant, MatchSpec)`` through the engine's plan-cache
keying hook (so two tenants with identical specs never share grow
capacities or trace history), two bounded request queues (one per query
target), and the double buffer itself:

``live``     the published immutable ``DDMSnapshot`` readers query —
             swapped atomically (a Python reference assignment), never
             mutated.
``pending``  the store version a rebuild has been requested for; the
             rebuild worker captures the store under ``lock``, builds
             trees off-lock into the shadow, and publishes.

Writers (``apply_moves``) touch only the store; readers touch only
``live``; the single rebuild path is what moves data between them, so a
query observes the captured region set in full — old or new, never a
torn mix.

Move batches are padded to power-of-two sizes (repeat-last-move
padding, which the service's last-write-wins dedup collapses to a
no-op) so a churn stream with drifting batch sizes retraces the
update path O(lg B) times total, mirroring the engine's grow policy.
"""
from __future__ import annotations

import threading

import numpy as np

from ..core.dynamic import DDMService, DDMSnapshot
from ..core.engine import MatchSpec
from ..core.regions import Regions
from .admission import AdmissionPolicy, TenantQueue
from .batching import TARGETS


def pad_moves_pow2(idx: np.ndarray, lo: np.ndarray, hi: np.ndarray):
    """Pad a move batch to the next power of two by repeating its last
    entry — identical store effect (last-write-wins dedup), one static
    shape per pow2 bucket instead of one per distinct batch size."""
    b = idx.shape[0]
    if b == 0:
        return idx, lo, hi
    cap = 1 << max(b - 1, 0).bit_length() if b > 1 else 1
    if cap == b:
        return idx, lo, hi
    pad = cap - b
    return (np.concatenate([idx, np.repeat(idx[-1:], pad)]),
            np.concatenate([lo, np.repeat(lo[-1:], pad, axis=0)]),
            np.concatenate([hi, np.repeat(hi[-1:], pad, axis=0)]))


class Tenant:
    """One namespace's full serving state (see module docstring)."""

    def __init__(self, name: str, S: Regions, U: Regions, *,
                 spec: MatchSpec | None = None, cap_hint: int = 64,
                 admission: AdmissionPolicy, plan_key):
        self.name = name
        self.svc = DDMService(S, U, cap_hint=cap_hint, spec=spec,
                              plan_key=plan_key)
        self.lock = threading.Lock()        # guards store mutation+capture
        self.queues = {t: TenantQueue(name, admission) for t in TARGETS}
        # the double buffer: readers take `live` by reference (atomic
        # under the GIL), the rebuild worker swaps a fresh snapshot in
        self.live: DDMSnapshot = self.svc.snapshot()
        self.pending_version: int | None = None

    @property
    def plan(self):
        return self.svc.plan

    @property
    def store_version(self) -> int:
        return self.svc.version

    @property
    def staleness(self) -> int:
        """Applied-but-unpublished update batches (the response bound)."""
        return self.svc.version - self.live.version

    def queue_depth(self) -> int:
        return sum(len(q) for q in self.queues.values())

    # -- write path ----------------------------------------------------------
    def apply_moves(self, kind: str, idx, new_lo, new_hi) -> int:
        """Validate + apply one churn batch; marks a rebuild pending.

        Never touches ``live`` — readers keep answering from the
        published snapshot until the rebuild worker swaps.
        """
        idx = np.atleast_1d(np.asarray(idx))
        new_lo = np.asarray(new_lo, np.float32).reshape(idx.shape[0], -1)
        new_hi = np.asarray(new_hi, np.float32).reshape(idx.shape[0], -1)
        if np.issubdtype(idx.dtype, np.integer):
            idx, new_lo, new_hi = pad_moves_pow2(idx, new_lo, new_hi)
        with self.lock:
            moved = self.svc.apply_moves(kind, idx, new_lo, new_hi)
            if moved:
                self.pending_version = self.svc.version
        return moved

    # -- rebuild path (the shadow side of the double buffer) -----------------
    def capture_for_rebuild(self):
        """Store view for the rebuild worker (None when already fresh)."""
        with self.lock:
            if self.svc.version == self.live.version:
                self.pending_version = None
                return None
            return self.svc.capture()

    def publish(self, snap: DDMSnapshot) -> None:
        """Atomically swap the shadow snapshot in (monotone versions)."""
        with self.lock:
            if snap.version >= self.live.version:
                self.live = snap
            if (self.pending_version is not None
                    and snap.version >= self.pending_version):
                self.pending_version = None
