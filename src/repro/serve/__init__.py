"""repro.serve — the production DDM serving layer.

Multi-tenant, asynchronous serving on top of the ``MatchSpec →
build_plan → MatchPlan`` engine and ``DDMService``: per-tenant
namespaces with one memoized plan per ``(tenant, MatchSpec)``, request
batching + admission control (max-batch/max-delay coalescing, bounded
queues, explicit shed/reject), double-buffered interval-tree rebuilds
so ``update_regions`` churn never blocks readers (every response
carries a snapshot version + staleness bound), and a JSON metrics
surface for the bench gate.

    from repro.serve import DDMServer

    server = DDMServer(compilation_cache=True)
    server.add_tenant("sim-a", S, U)
    server.start()
    fut = server.submit("sim-a", "sub", lo, hi)   # future → QueryResult
    server.update_regions("sim-a", "sub", idx, new_lo, new_hi)
    ...
    server.stop()

``python -m repro.serve --smoke`` runs the self-checking multi-tenant
churn harness (set-parity vs a brute oracle, zero steady-state
retraces).  The LM inference demo that used to live at
``repro.launch.serve`` is now ``repro.launch.lm_serve``.
"""
from .admission import AdmissionError, AdmissionPolicy
from .batching import BatchPolicy, QueryResult
from .compile_cache import enable as enable_compilation_cache
from .metrics import Metrics
from .server import DDMServer
from .tenancy import Tenant

__all__ = [
    "DDMServer", "Tenant", "Metrics",
    "AdmissionError", "AdmissionPolicy", "BatchPolicy", "QueryResult",
    "enable_compilation_cache",
]
