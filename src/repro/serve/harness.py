"""Self-checking multi-tenant churn harness (the ``--smoke`` driver and
the serving benchmark's engine).

One *tick* per tenant = one production serving cycle:

1. ``update_regions`` — a validated move batch lands in the store (the
   published snapshot is now stale by one version);
2. a query burst answered **mid-churn** — before any rebuild runs —
   checked set-identical to the brute oracle of the *snapshot* it was
   answered from (staleness is bounded and visible, answers are still
   exact for their version);
3. the double-buffered rebuild publishes;
4. a second burst answered at staleness 0, checked against the fresh
   oracle.

After ``warmup`` ticks the remaining ticks run inside
``analysis.retrace.no_retrace`` over every tenant's plan — steady-state
churn must not retrace (move batches are pow2-padded, query batches are
sentinel-padded to ``max_batch``, grow capacities are memoized).
"""
from __future__ import annotations

import time

import numpy as np

from ..core.engine import MatchSpec
from ..core.regions import paper_workload
from .batching import BatchPolicy
from .admission import AdmissionPolicy
from .server import DDMServer

SPACE = 1.0e6


def make_query_boxes(rng, count: int, d: int, width: float = 5e3):
    lo = rng.uniform(0, SPACE - width, (count, d)).astype(np.float32)
    return lo, (lo + width).astype(np.float32)


def make_moves(rng, n: int, b: int, d: int):
    idx = rng.choice(n, size=min(b, n), replace=False)
    lo = rng.uniform(0, 0.9 * SPACE, (idx.shape[0], d)).astype(np.float32)
    hi = lo + rng.uniform(1.0, 5e3, (idx.shape[0], d)).astype(np.float32)
    return idx, lo, hi


def run_churn(*, tenants: int = 3, n_total: int = 2048, ticks: int = 6,
              warmup: int = 2, moves_per_tick: int = 64,
              queries_per_tick: int = 48, max_batch: int = 64,
              cap_hint: int = 512, seed: int = 0, d_cycle=(1, 2),
              oracle: bool = True, compilation_cache=None,
              threaded: bool = False, progress=None) -> dict:
    """Drive a ``DDMServer`` through sustained multi-tenant churn.

    Raises ``AssertionError`` on any parity or retrace violation.
    Returns summary stats (per-phase latencies in seconds, rebuild
    durations, the metrics dict) for benchmark rows.
    """
    from ..analysis.retrace import no_retrace

    server = DDMServer(batch=BatchPolicy(max_batch=max_batch),
                       admission=AdmissionPolicy(max_queue=16 * max_batch),
                       compilation_cache=compilation_cache or False)
    rngs = {}
    for i in range(tenants):
        name = f"tenant{i}"
        d = d_cycle[i % len(d_cycle)]
        S, U = paper_workload(seed=seed + i, n_total=n_total, alpha=5.0,
                              d=d)
        server.add_tenant(name, S, U,
                          spec=MatchSpec(algo="itm", capacity="grow",
                                         max_pairs=cap_hint),
                          cap_hint=cap_hint)
        rngs[name] = np.random.default_rng(seed + 100 + i)
    if threaded:
        server.start()

    stats = {"stale_query_s": [], "fresh_query_s": [],
             "rebuild_s": [], "parity_checks": 0, "tick_s": []}

    def burst(name, expect_stale: bool):
        """One query burst; returns futures -> verified results."""
        t = server.tenant(name)
        rng = rngs[name]
        q_lo, q_hi = make_query_boxes(rng, queries_per_tick, t.svc.d)
        targets = ["sub" if j % 2 == 0 else "upd"
                   for j in range(queries_per_tick)]
        futs = [server.submit(name, targets[j], q_lo[j], q_hi[j])
                for j in range(queries_per_tick)]
        if not threaded:
            server.pump(queries=True, rebuilds=False)
        results = [f.result(timeout=60.0) for f in futs]
        for j, res in enumerate(results):
            if expect_stale:
                assert res.staleness >= 1, (name, res)
            # parity: the answer must equal the brute oracle of the
            # exact snapshot version it was served from — a torn read
            # (mix of old and new extents) fails this for SOME box
            if oracle:
                snap = t.live if res.version == t.live.version else None
                if snap is not None:
                    want = snap.oracle_ids(targets[j], q_lo[j], q_hi[j])
                    got = res.id_set()
                    assert got == want, (
                        f"{name} tick parity: {len(got ^ want)} ids "
                        f"differ at version {res.version}")
                    stats["parity_checks"] += 1
        return results

    def tick(name):
        t = server.tenant(name)
        rng = rngs[name]
        t0 = time.perf_counter()
        idx, lo, hi = make_moves(rng, t.svc.s_lo.shape[0],
                                 moves_per_tick, t.svc.d)
        server.update_regions(name, "sub", idx, lo, hi)
        # mid-churn burst: answered from the stale snapshot, exact for
        # its version, staleness surfaced
        if not threaded:
            stale = burst(name, expect_stale=True)
            stats["stale_query_s"].extend(r.latency_s for r in stale)
            r0 = time.perf_counter()
            server.pump(queries=False, rebuilds=True)
            stats["rebuild_s"].append(time.perf_counter() - r0)
        else:
            # threaded mode: the rebuild worker races the burst; both
            # stale and fresh answers are legal, parity still holds
            stale = burst(name, expect_stale=False)
            stats["stale_query_s"].extend(r.latency_s for r in stale)
            deadline = time.perf_counter() + 60.0
            while (t.staleness and time.perf_counter() < deadline):
                time.sleep(1e-3)
            assert t.staleness == 0, f"{name}: rebuild never caught up"
        fresh = burst(name, expect_stale=False)
        for r in fresh:
            assert r.staleness == 0, (name, r)
        stats["fresh_query_s"].extend(r.latency_s for r in fresh)
        stats["tick_s"].append(time.perf_counter() - t0)

    names = [f"tenant{i}" for i in range(tenants)]
    for w in range(warmup):
        for name in names:
            tick(name)
        if progress:
            progress(f"warmup tick {w + 1}/{warmup} done")

    # summary percentiles reflect steady state only: warmup ticks carry
    # first-compile latency, which gets its own (ungated) stat
    def pctl(vals, q):
        return float(np.percentile(np.asarray(vals), q)) if vals else 0.0

    stats["warmup_p99_query_s"] = pctl(
        stats["stale_query_s"] + stats["fresh_query_s"], 99)
    for key in ("stale_query_s", "fresh_query_s", "rebuild_s", "tick_s"):
        stats[key] = []

    plans = [server.tenant(n).plan for n in names]
    with no_retrace(*plans):
        for s in range(ticks - warmup):
            for name in names:
                tick(name)
            if progress:
                progress(f"steady tick {s + 1}/{ticks - warmup} done")

    if threaded:
        server.stop()

    stats.update({
        "p50_query_s": pctl(stats["stale_query_s"]
                            + stats["fresh_query_s"], 50),
        "p99_query_s": pctl(stats["stale_query_s"]
                            + stats["fresh_query_s"], 99),
        "p99_stale_query_s": pctl(stats["stale_query_s"], 99),
        "rebuild_p50_s": pctl(stats["rebuild_s"], 50),
        "rebuild_p99_s": pctl(stats["rebuild_s"], 99),
        "metrics": server.metrics_dict(),
    })
    return stats
