"""Fault-tolerant training runtime.

Design (DESIGN.md §5, 1000+ node posture):
  * periodic async sharded checkpoints (atomic rename — a torn write can
    never be restored);
  * restart = restore latest checkpoint + replay the deterministic data
    pipeline from that step: the combination makes a failed run
    *bit-identical* to an uninterrupted one (asserted in tests);
  * failure injection hooks simulate node loss at arbitrary steps;
  * straggler/elastic posture: data shards are pure functions of
    (seed, step, host) — a replaced host needs no coordinator handshake,
    and re-scaling re-partitions the host index space (checkpoint
    restore reshards via the DDM plan in checkpoint.sharded).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Callable

import jax
import numpy as np

from ..checkpoint.sharded import AsyncSaver, latest_step, restore, save
from ..data.pipeline import DataConfig, SyntheticTokens
from ..models import transformer as T
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init, adamw_update


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests / chaos drills)."""


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 5
    n_ckpt_shards: int = 1
    async_ckpt: bool = False
    log_every: int = 1


class Trainer:
    def __init__(self, model_cfg: ModelConfig, opt_cfg: AdamWConfig,
                 tcfg: TrainerConfig, data_cfg: DataConfig,
                 seed: int = 0):
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.data = SyntheticTokens(data_cfg)
        self._seed = seed
        self._saver = AsyncSaver()
        self._step_fn = jax.jit(self._make_step())

    def _make_step(self):
        mcfg, ocfg = self.model_cfg, self.opt_cfg

        def step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: T.loss_fn(p, batch, mcfg), has_aux=True)(params)
            params, opt_state, om = adamw_update(params, grads, opt_state,
                                                 ocfg)
            return params, opt_state, {"loss": loss, **metrics, **om}

        return step

    def init_state(self):
        params = T.init_params(self.model_cfg, jax.random.PRNGKey(
            self._seed))
        return params, adamw_init(params)

    # -- one contiguous attempt (may die on injected failure) -------------
    def run(self, n_steps: int, *,
            failure_at: int | None = None,
            on_step: Callable[[int, dict], None] | None = None):
        params, opt_state = self.init_state()
        start = 0
        last = latest_step(self.tcfg.ckpt_dir)
        if last is not None:
            state = restore(self.tcfg.ckpt_dir, last,
                            {"params": params, "opt": opt_state},
                            n_shards_new=self.tcfg.n_ckpt_shards)
            params, opt_state = state["params"], state["opt"]
            start = last
        metrics = {}
        for step in range(start, n_steps):
            if failure_at is not None and step == failure_at:
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = {"tokens": self.data.global_batch(step)}
            if self.model_cfg.family == "audio":
                rng = np.random.default_rng(step)
                batch["frames"] = rng.normal(size=(
                    self.data.cfg.global_batch, self.model_cfg.enc_frames,
                    self.model_cfg.d_model)).astype(np.float32) * 0.1
            params, opt_state, metrics = self._step_fn(params, opt_state,
                                                       batch)
            done = step + 1
            if done % self.tcfg.ckpt_every == 0 or done == n_steps:
                tree = {"params": params, "opt": opt_state}
                if self.tcfg.async_ckpt:
                    self._saver.save(self.tcfg.ckpt_dir, done, tree,
                                     n_shards=self.tcfg.n_ckpt_shards)
                else:
                    save(self.tcfg.ckpt_dir, done, tree,
                         n_shards=self.tcfg.n_ckpt_shards)
            if on_step is not None:
                on_step(step, metrics)
        self._saver.wait()
        return params, opt_state, metrics

    # -- supervised attempts with restart ---------------------------------
    def run_resilient(self, n_steps: int, *, failures: tuple[int, ...] = (),
                      max_restarts: int = 8, on_step=None):
        """Run to completion, restarting from the latest checkpoint after
        each injected failure (the restart path real node loss takes)."""
        pending = list(failures)
        for _ in range(max_restarts + 1):
            try:
                fail_at = pending[0] if pending else None
                out = self.run(n_steps, failure_at=fail_at,
                               on_step=on_step)
                return out
            except SimulatedFailure:
                pending.pop(0)
                continue
        raise RuntimeError("exceeded max_restarts")
