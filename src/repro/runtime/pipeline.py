"""GPipe-style pipeline parallelism over the 'pod' axis (optional
schedule; DESIGN.md §5).

The layer stack is split into ``n_stages`` contiguous stage groups; each
stage lives on one slice of the pipeline axis.  Microbatches stream
through under ``shard_map``: every clock tick each stage applies its
layers to its current microbatch and passes activations to the next
stage with ``ppermute`` (the classic bubble schedule: ``M + S − 1``
ticks for M microbatches, S stages; bubble fraction (S−1)/(M+S−1)).

This is the *inference/forward* pipeline used to validate the schedule
and its collectives against the single-device stack (bit-comparable in
fp32); the training default remains DP-across-pods with compressed
gradient all-reduce, which EXPERIMENTS §Perf shows is collective-cheaper
at our shapes than a 2-stage pipeline for these models.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

# ``jax.shard_map`` is the new-JAX spelling; older versions ship it under
# jax.experimental with the same signature.
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - exercised only on old JAX
    from jax.experimental.shard_map import shard_map as _shard_map

# ``pvary`` marks a carry as axis-varying for new-JAX shard_map's varying
# -manual-axes type system; older shard_map has no such tracking, where
# the identity is the correct no-op.
_pvary = getattr(jax.lax, "pvary", lambda x, axis: x)

AXIS = "stage"


def pipeline_forward(stacked_params, x, layer_apply, *, mesh: Mesh,
                     n_microbatches: int):
    """Run x through L stacked layers split across the 'stage' axis.

    stacked_params: pytree with leading layer axis L (L % n_stages == 0).
    x: (B, ...) activations, B % n_microbatches == 0.
    layer_apply(p_layer, x_mb) -> x_mb.
    """
    n_stages = mesh.devices.size
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    assert L % n_stages == 0, (L, n_stages)
    B = x.shape[0]
    assert B % n_microbatches == 0
    mb = B // n_microbatches

    # reshape params to (stages, layers_per_stage, ...) and microbatches
    per = L // n_stages
    sp = jax.tree.map(
        lambda a: a.reshape((n_stages, per) + a.shape[1:]), stacked_params)
    xmb = x.reshape((n_microbatches, mb) + x.shape[1:])

    def stage_body(params_stage, xs):
        """One device: params for its `per` layers; xs: all microbatches
        (streamed: device 0 feeds them in)."""
        me = jax.lax.axis_index(AXIS)
        params_stage = jax.tree.map(lambda a: a[0], params_stage)

        def apply_stage(xin):
            def body(c, pl):
                return layer_apply(pl, c), None
            out, _ = jax.lax.scan(body, xin, params_stage)
            return out

        ticks = n_microbatches + n_stages - 1
        # carries must be stage-varying for the shard_map type system
        buf = _pvary(jnp.zeros_like(xs[0]), AXIS)
        outs = _pvary(jnp.zeros_like(xs), AXIS)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any); others use received
            feed = jnp.where(t < n_microbatches,
                             xs[jnp.minimum(t, n_microbatches - 1)],
                             jnp.zeros_like(buf))
            cur = jnp.where(me == 0, feed, buf)  # feed varies via buf
            y = apply_stage(cur)
            # pass to next stage
            nxt = jax.lax.ppermute(
                y, AXIS, [(i, (i + 1) % n_stages) for i in
                          range(n_stages)])
            # last stage emits microbatch (t - (n_stages - 1))
            emit_idx = t - (n_stages - 1)
            emit = (me == n_stages - 1) & (emit_idx >= 0)
            idxc = jnp.clip(emit_idx, 0, n_microbatches - 1)
            outs = outs.at[idxc].set(jnp.where(emit, y, outs[idxc]))
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs),
                                    jnp.arange(ticks))
        # broadcast final outputs from the last stage to all (mask+psum)
        outs = jnp.where(me == n_stages - 1, outs, jnp.zeros_like(outs))
        outs = jax.lax.psum(outs, AXIS)
        return outs[None]

    f = _shard_map(
        stage_body, mesh=mesh,
        in_specs=(P(AXIS), P()),
        out_specs=P(AXIS),
    )
    outs = f(sp, xmb)            # (n_stages, nmb, mb, ...) replicated rows
    return outs[0].reshape((B,) + x.shape[1:])
