"""Sharded, atomic, async-capable checkpointing (no external deps).

Layout (one directory per step)::

    <dir>/step_0000010/
        manifest.json          # leaf paths, shapes, dtypes, shard ranges
        shard_000.npz ...      # leaves split along axis 0 into n_shards

Each shard file corresponds to a host's slice in a multi-host run (on
this single-host container the split is simulated but the format is the
real one).  Writes go to ``<name>.tmp`` then ``os.rename`` — a torn write
can never be mistaken for a valid checkpoint (restart safety).  Async
mode device_gets the tree, then a daemon thread serializes.

Restoring to a different shard count is *elastic resharding*: each new
shard's row range is intersected with the old ranges — a 1-D interval
matching problem solved by ``repro.core`` (the paper's algorithm
planning the framework's own data movement; DESIGN.md §3).
"""
from __future__ import annotations

import json
import os
import threading
from pathlib import Path

import jax
import numpy as np

from ..core import MatchSpec, Regions, build_plan


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(e, "key", getattr(e, "name", e)))
                        for e in path)
        out.append((name, leaf))
    return out


def _split_ranges(n_rows: int, n_shards: int):
    cuts = np.linspace(0, n_rows, n_shards + 1).astype(np.int64)
    return [(int(cuts[i]), int(cuts[i + 1])) for i in range(n_shards)]


def save(ckpt_dir: str | os.PathLike, step: int, tree, *,
         n_shards: int = 1) -> Path:
    """Write a checkpoint synchronously; returns the final directory."""
    base = Path(ckpt_dir)
    final = base / f"step_{step:07d}"
    tmp = base / f"step_{step:07d}.tmp"
    if tmp.exists():
        import shutil
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = [(name, np.asarray(jax.device_get(leaf)))
              for name, leaf in _leaf_paths(tree)]
    manifest = {"step": step, "n_shards": n_shards, "leaves": []}
    shards: list[dict] = [{} for _ in range(n_shards)]
    for li, (name, arr) in enumerate(leaves):
        key = f"leaf_{li}"
        rows = arr.shape[0] if arr.ndim else 1
        ranges = _split_ranges(rows, n_shards)
        manifest["leaves"].append({
            "name": name, "key": key, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "ranges": ranges})
        flat = arr.reshape(rows, -1) if arr.ndim else arr.reshape(1, 1)
        for si, (lo, hi) in enumerate(ranges):
            shards[si][key] = flat[lo:hi]
    for si, blob in enumerate(shards):
        np.savez(tmp / f"shard_{si:03d}.npz", **blob)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncSaver:
    """Device-get on the caller thread, serialize on a daemon thread."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self.last_error: BaseException | None = None

    def save(self, ckpt_dir, step, tree, *, n_shards: int = 1):
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                 tree)

        def work():
            try:
                save(ckpt_dir, step, host_tree, n_shards=n_shards)
            except BaseException as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            raise self.last_error


def latest_step(ckpt_dir) -> int | None:
    base = Path(ckpt_dir)
    if not base.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in base.glob("step_*")
             if p.is_dir() and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def _reshard_plan(old_ranges, new_ranges):
    """Which old shards overlap each new shard's row range — computed by
    the paper's interval matcher (half-open row intervals).

    Zero-row shard ranges (lo == hi, produced when n_shards > n_rows)
    hold no data and would violate the matcher's non-empty-interval
    precondition — they are dropped before matching and can appear in no
    plan entry."""
    new_ids = [i for i, (lo, hi) in enumerate(new_ranges) if lo < hi]
    old_ids = [i for i, (lo, hi) in enumerate(old_ranges) if lo < hi]
    if not new_ids or not old_ids:
        return {}
    S = Regions(np.asarray([[new_ranges[i][0]] for i in new_ids],
                           np.float32),
                np.asarray([[new_ranges[i][1]] for i in new_ids],
                           np.float32))
    U = Regions(np.asarray([[old_ranges[i][0]] for i in old_ids],
                           np.float32),
                np.asarray([[old_ranges[i][1]] for i in old_ids],
                           np.float32))
    cap = (len(new_ids) + len(old_ids)) * 2 + 8
    match_plan = build_plan(MatchSpec(algo="sbm", capacity="fixed",
                                      max_pairs=cap), S.n, U.n, 1)
    pairs, count = match_plan.pairs(S, U)
    pairs = np.asarray(pairs)
    pairs = pairs[pairs[:, 0] >= 0]
    plan: dict[int, list[int]] = {}
    for new_i, old_i in pairs:
        plan.setdefault(new_ids[int(new_i)], []).append(old_ids[int(old_i)])
    for v in plan.values():
        v.sort()
    return plan


def restore(ckpt_dir, step: int, template, *, n_shards_new: int = 1):
    """Restore a checkpoint into ``template``'s treedef, resharding from
    the stored shard count to ``n_shards_new`` via the DDM plan."""
    final = Path(ckpt_dir) / f"step_{step:07d}"
    manifest = json.loads((final / "manifest.json").read_text())
    files = {si: np.load(final / f"shard_{si:03d}.npz")
             for si in range(manifest["n_shards"])}

    arrays = {}
    for rec in manifest["leaves"]:
        rows = rec["shape"][0] if rec["shape"] else 1
        new_ranges = _split_ranges(rows, n_shards_new)
        old_ranges = [tuple(r) for r in rec["ranges"]]
        plan = _reshard_plan(old_ranges, new_ranges)
        pieces = []
        for ni, (nlo, nhi) in enumerate(new_ranges):
            if nlo == nhi:
                continue
            for oi in plan.get(ni, []):
                olo, ohi = old_ranges[oi]
                lo = max(nlo, olo)
                hi = min(nhi, ohi)
                if lo >= hi:
                    continue
                chunk = files[oi][rec["key"]][lo - olo: hi - olo]
                pieces.append(chunk)
        full = np.concatenate(pieces, axis=0) if pieces else \
            files[0][rec["key"]]
        arrays[rec["name"]] = full.reshape(rec["shape"]).astype(
            rec["dtype"])

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(e, "key", getattr(e, "name", e)))
                        for e in path)
        arr = arrays[name]
        assert tuple(arr.shape) == tuple(leaf.shape), (name, arr.shape,
                                                       leaf.shape)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
