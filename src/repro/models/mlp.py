"""Dense SwiGLU MLP sublayer."""
from __future__ import annotations

import jax

from .config import ModelConfig
from .layers import linear, linear_init, swiglu
from .sharding import constrain


def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": linear_init(ks[0], cfg.d_model, d_ff),
        "w_up": linear_init(ks[1], cfg.d_model, d_ff),
        "w_down": linear_init(ks[2], d_ff, cfg.d_model,
                              std=d_ff ** -0.5
                              / max(2 * cfg.n_layers, 1) ** 0.5),
    }


def mlp_apply(p, x, dtype=None):
    dt = dtype or x.dtype
    h = swiglu(linear(p["w_gate"], x, dt), linear(p["w_up"], x, dt))
    h = constrain(h, "dp", None, "tp")
    return constrain(linear(p["w_down"], h, dt), "dp", None, None)
