"""Primitive layers: linear, norms, embeddings, RoPE.

Parameters are plain nested dicts of jnp arrays (master fp32); compute
casts to the config dtype at use.  Initializers take explicit PRNG keys;
everything here is shape-polymorphic and jit/vmap/shard_map friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def truncated_normal(key, shape, std):
    return std * jax.random.truncated_normal(key, -3.0, 3.0, shape,
                                             jnp.float32)


# -- linear -----------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, bias: bool = False,
                std: float | None = None):
    std = std if std is not None else d_in ** -0.5
    p = {"w": truncated_normal(key, (d_in, d_out), std)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(p, x, dtype=jnp.bfloat16):
    y = x.astype(dtype) @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


# -- norms --------------------------------------------------------------------

def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-5):
    # fp32 only inside the reduction (dtype=f32 fuses the convert into
    # the reduce): a wholesale x.astype(f32) materializes an fp32 copy
    # of the saved residual stack in backward (XLA hoists the convert
    # out of the layer loop) — see DESIGN §4b.
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                   dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * p["scale"].astype(x.dtype)


def rms_headnorm(x, eps: float = 1e-6):
    """Parameter-free per-head RMS norm (qk-norm, mamba gated norm)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# -- embedding ----------------------------------------------------------------

def embed_init(key, vocab: int, d: int):
    return {"table": truncated_normal(key, (vocab, d), d ** -0.5)}


def embed(p, tokens, dtype=jnp.bfloat16):
    return p["table"].astype(dtype)[tokens]


# -- rotary positional embedding ---------------------------------------------

def rope_angles(positions: Array, dim: int, theta: float) -> tuple[Array, Array]:
    """cos/sin tables (..., dim/2) for integer positions."""
    inv = 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (..., seq, heads, dim); cos/sin: (seq, dim/2) or broadcastable."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


# -- activations ----------------------------------------------------------------

def swiglu(gate, up):
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def softmax_xent(logits: Array, labels: Array) -> Array:
    """Token-mean cross entropy; logits cast to f32 for the reduction."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
