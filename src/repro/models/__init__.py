"""Model library."""
from .config import ModelConfig
from . import layers, attention, mlp, moe, ssm, transformer
