"""Mamba-2 (SSD, state-space duality) mixer — arXiv:2405.21060.

Chunked SSD algorithm: the sequence is split into chunks of length Q;
within a chunk the recurrence is computed in its dual quadratic
("attention-like") form on the MXU, across chunks a lax.scan carries the
(heads, head_dim, d_state) SSM state — the same intra/inter two-level
scan shape as the paper's Alg. 7, one level up.  Single-token decode is
the bare recurrence on a carried state (O(1) in context length — this is
why the ssm/hybrid archs run the long_500k shape natively).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import linear, linear_init, truncated_normal
from .sharding import constrain

Array = jax.Array


def _inv_softplus(x):
    return x + jnp.log(-jnp.expm1(-x))


def mamba2_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    d, di, ns = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.n_ssm_heads
    conv_ch = di + 2 * ns
    dt = jnp.exp(jax.random.uniform(ks[3], (nh,), jnp.float32,
                                    np.log(1e-3), np.log(1e-1)))
    a_init = jax.random.uniform(ks[4], (nh,), jnp.float32, 1.0, 16.0)
    return {
        "in_proj": linear_init(ks[0], d, 2 * di + 2 * ns + nh),
        "conv_w": truncated_normal(ks[1], (cfg.conv_width, conv_ch),
                                   conv_ch ** -0.5),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(a_init),
        "dt_bias": _inv_softplus(dt),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": {"scale": jnp.ones((di,), jnp.float32)},
        "out_proj": linear_init(ks[2], di, d,
                                std=di ** -0.5
                                / max(2 * cfg.n_layers, 1) ** 0.5),
    }


def mamba2_cache_init(cfg: ModelConfig, batch: int, dtype):
    di, ns = cfg.d_inner, cfg.ssm_state
    nh, hd = cfg.n_ssm_heads, cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * ns), dtype),
        "ssm": jnp.zeros((batch, nh, hd, ns), jnp.float32),
    }


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv, width W.  xbc: (B,S,C); state: (B,W-1,C)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    new_state = xp[:, -(W - 1):, :] if W > 1 else None
    out = sum(xp[:, i: i + xbc.shape[1], :] * w[i].astype(xbc.dtype)
              for i in range(W))
    out = out + b.astype(xbc.dtype)
    return jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype), new_state


def _segsum(a):
    """a: (..., Q) → (..., Q, Q) with [i,j] = sum_{k=j+1..i} a_k (i≥j)."""
    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(xdt, a, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD.  xdt: (B,S,H,P) (inputs pre-scaled by dt),
    a: (B,S,H) log-decay (=dt·A, negative), Bm/Cm: (B,S,N) shared across
    heads (single group).  Returns (y (B,S,H,P), final state (B,H,P,N))."""
    b, s, h, p = xdt.shape
    n = Bm.shape[-1]
    Q = min(chunk, s)
    pad = (-s) % Q
    if pad:
        # a=0 pads: chunk decay exp(0)=1 and zero input — the carried
        # state passes through unchanged and padded outputs are trimmed.
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    s_p = s + pad
    nc = s_p // Q
    xc = xdt.reshape(b, nc, Q, h, p)
    ac = a.reshape(b, nc, Q, h).astype(jnp.float32)
    Bc = Bm.reshape(b, nc, Q, n)
    Cc = Cm.reshape(b, nc, Q, n)

    acum = jnp.cumsum(ac, axis=2)                        # (b,nc,Q,h)
    L = jnp.exp(_segsum(ac.swapaxes(2, 3)))              # (b,nc,h,Q,Q)

    # intra-chunk (dual quadratic form)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc,
                        preferred_element_type=jnp.float32)
    y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp",
                        scores, L, xc.astype(jnp.float32))

    # chunk-final states
    decay_states = jnp.exp(acum[:, :, -1:, :] - acum)    # (b,nc,Q,h)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn",
                        Bc.astype(jnp.float32), decay_states,
                        xc.astype(jnp.float32))

    # inter-chunk recurrence
    chunk_decay = jnp.exp(acum[:, :, -1, :])             # (b,nc,h)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def step(hprev, args):
        st, dec = args                                   # (b,h,p,n),(b,h)
        hnew = hprev * dec[:, :, None, None] + st
        return hnew, hprev

    hlast, hprevs = jax.lax.scan(
        step, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    hprevs = hprevs.swapaxes(0, 1)                        # (b,nc,h,p,n)

    # off-diagonal (carried state) contribution
    out_decay = jnp.exp(acum)                             # (b,nc,Q,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp",
                       Cc.astype(jnp.float32), hprevs, out_decay)
    y = (y_diag + y_off).reshape(b, s_p, h, p)[:, :s]
    return y, hlast


def mamba2_apply(p, x, cfg: ModelConfig, *, cache: dict | None = None):
    """One Mamba-2 mixer.  x: (B,S,d).  Returns (y, new_cache).

    Training/prefill: cache=None (or a fresh cache to fill, S ≥ 1).
    Decode: S == 1 with a carried cache.
    """
    B, S, d = x.shape
    dt_ = x.dtype
    di, ns = cfg.d_inner, cfg.ssm_state
    nh, hd = cfg.n_ssm_heads, cfg.ssm_head_dim

    proj = linear(p["in_proj"], x, dt_)
    z, xi, Bm, Cm, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + ns, 2 * di + 2 * ns], axis=-1)
    xbc = constrain(jnp.concatenate([xi, Bm, Cm], axis=-1), "dp", None, "tp")

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xi, Bm, Cm = jnp.split(xbc, [di, di + ns], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                  # (B,S,nh)
    A = -jnp.exp(p["A_log"])                              # (nh,)
    a = dt * A                                            # log decay
    xh = constrain(xi.reshape(B, S, nh, hd), "dp", None, "tp", None)
    xdt = xh.astype(jnp.float32) * dt[..., None]

    if cache is not None and S == 1:
        # bare recurrence
        h0 = cache["ssm"]
        dec = jnp.exp(a[:, 0, :])                         # (B,nh)
        upd = jnp.einsum("bn,bhp->bhpn", Bm[:, 0].astype(jnp.float32),
                         xdt[:, 0])
        hnew = h0 * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32),
                       hnew)[:, None]                     # (B,1,nh,hd)
        new_cache = {"conv": new_conv, "ssm": hnew}
    else:
        h0 = cache["ssm"] if cache is not None else None
        y, hlast = _ssd_chunked(xdt, a, Bm, Cm, cfg.ssd_chunk, h0)
        new_cache = None if cache is None else {"conv": new_conv,
                                                "ssm": hlast}

    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(dt_)
    # gated RMS norm
    g = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    var = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    g = (g.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * p["norm"]["scale"]).astype(dt_)
    out = constrain(linear(p["out_proj"], g, dt_), "dp", None, None)
    return out, new_cache
