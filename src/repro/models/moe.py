"""Mixture-of-Experts sublayer — GShard/Switch-style capacity dispatch.

TPU-native formulation: tokens are processed in *groups* (the batch/
sequence grid reshaped to (G, Tg, d)); within each group every top-k slot
builds a (Tg, E, C) one-hot dispatch tensor and routes tokens with three
einsums (dispatch → expert SwiGLU → combine).  The group axis carries the
data sharding, the expert axis carries expert parallelism ('model'), so
the dispatch einsums lower to all-to-all-free sharded matmuls under
GSPMD, and per-device memory is (Tg·E·C) per slot, independent of global
batch.

Capacity per group per slot C = max(4, ceil(Tg/E · capacity_factor));
overflow tokens are dropped (standard dropping MoE; the residual stream
carries them).  Aux load-balance loss is returned to the caller.

DeepSeek-V2 style: ``n_shared_experts`` dense shared experts run on every
token; ``first_dense_layers`` layers use the plain MLP instead (handled
by the stack).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import linear_init, swiglu, truncated_normal
from .sharding import constrain
from .mlp import mlp_init, mlp_apply

Array = jax.Array


def moe_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    std_in = d ** -0.5
    std_out = f ** -0.5 / max(2 * cfg.n_layers, 1) ** 0.5
    p = {
        "router": linear_init(ks[0], d, E),
        "w_gate": truncated_normal(ks[1], (E, d, f), std_in),
        "w_up": truncated_normal(ks[2], (E, d, f), std_in),
        "w_down": truncated_normal(ks[3], (E, f, d), std_out),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg,
                               d_ff=cfg.n_shared_experts * cfg.moe_d_ff)
    return p


def moe_apply(p, x, cfg: ModelConfig, *, group_tokens: int = 1024):
    """x: (B, S, d) → (y, aux_loss)."""
    B, S, d = x.shape
    dt = x.dtype
    E, k = cfg.n_experts, cfg.top_k
    gt = min(group_tokens, S)
    while S % gt:
        gt -= 1
    G = B * (S // gt)
    xg = constrain(x.reshape(G, gt, d), "dp", None, None)
    C = max(4, math.ceil(gt / E * cfg.capacity_factor))

    # router matmul in model dtype (an f32 upcast of xg materializes a
    # full activation copy per layer); logits upcast after — routing
    # decisions tolerate bf16 scores.  Keep the expert axis REPLICATED
    # here: top_k over an expert-sharded axis forces XLA into an
    # involuntary full rematerialization of the (tokens, E) tensor.
    logits = (xg @ p["router"]["w"].astype(dt)).astype(jnp.float32)
    logits = constrain(logits, "dp", None, None)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, k)                       # (G,Tg,k)
    vals = vals / jnp.sum(vals, axis=-1, keepdims=True)       # renorm

    # aux load-balance loss (Switch eq. 4, over all slots)
    me = jnp.mean(probs, axis=(0, 1))                         # (E,)
    assign = jax.nn.one_hot(idx, E, dtype=jnp.float32)        # (G,Tg,k,E)
    ce = jnp.mean(jnp.sum(assign, axis=2), axis=(0, 1))
    aux = E * jnp.sum(me * ce) / k

    @jax.checkpoint
    def one_slot(xg, slot_idx, slot_vals):
        # rematted: dispatch/combine one-hots and expert activations are
        # recomputed in backward instead of living for all k slots.
        e_onehot = jax.nn.one_hot(slot_idx, E, dtype=jnp.int32)
        rank = jnp.cumsum(e_onehot, axis=1) - 1               # (G,Tg,E)
        my_rank = jnp.sum(rank * e_onehot, axis=-1)           # (G,Tg)
        keep = my_rank < C
        pos = jax.nn.one_hot(jnp.where(keep, my_rank, C), C, dtype=dt)
        disp = e_onehot.astype(dt)[..., None] * pos[:, :, None, :]
        xe = jnp.einsum("gtec,gtd->gecd", disp, xg,
                        preferred_element_type=jnp.float32).astype(dt)
        xe = constrain(xe, "dp", "tp", None, None)
        h = swiglu(
            jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dt)),
            jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dt)))
        ye = constrain(
            jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt)),
            "dp", "tp", None, None)
        w_slot = (slot_vals * keep).astype(dt)                # (G,Tg)
        comb = disp * w_slot[..., None, None]
        return jnp.einsum("gtec,gecd->gtd", comb, ye,
                          preferred_element_type=jnp.float32).astype(dt)

    out = jnp.zeros_like(xg)
    for slot in range(k):
        out = out + one_slot(xg, idx[..., slot], vals[..., slot])

    y = out.reshape(B, S, d)
    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, dt)
    return y, aux.astype(jnp.float32)
