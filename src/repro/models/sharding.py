"""Activation sharding constraints (logical-axis indirection).

Models call ``constrain(x, "dp", None, "tp")`` with *logical* axis names;
the mapping to mesh axes is resolved against the ambient mesh installed
by ``jax.set_mesh`` in the launcher:

    "dp" → ("pod", "data")  (whichever exist)   — batch / fsdp-gather dim
    "tp" → "model"                               — heads / ffn / vocab
    "sp" → "data"                                — sequence (long-context)

Outside any mesh (unit tests, single-device runs) this is a no-op, so
model code never depends on launch topology.  Dims whose size doesn't
divide the axis product are dropped (same rule as launch.partition.sanitize).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _ambient_mesh():
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        mesh = get_abstract()
        if mesh is None or not mesh.axis_names:
            return None
        return mesh
    # older JAX: the ambient *physical* mesh installed by `with mesh:`
    try:  # pragma: no cover - exercised only on old JAX
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
    except Exception:
        return None
    if mesh is None or mesh.empty or not mesh.axis_names:
        return None
    return mesh


def _axis_sizes(mesh) -> dict:
    sizes = getattr(mesh, "axis_sizes", None)
    if sizes is not None:
        return dict(zip(mesh.axis_names, sizes))
    return {a: mesh.shape[a] for a in mesh.axis_names}


def _resolve(name, axis_names):
    if name is None:
        return None
    if name == "dp":
        axes = tuple(a for a in ("pod", "data") if a in axis_names)
        return axes if axes else None
    if name == "tp":
        return "model" if "model" in axis_names else None
    if name == "sp":
        return "data" if "data" in axis_names else None
    if name == "tpseq":   # Megatron-style sequence parallelism: the
        # residual stream's seq dim shards over the tensor axis between
        # layers; TP regions gather/scatter at entry/exit.
        return "model" if "model" in axis_names else None
    return name if name in axis_names else None


def constrain(x, *logical):
    """Apply with_sharding_constraint under the ambient mesh (or no-op).

    ``REPRO_DISABLE_CONSTRAINTS`` env var (comma list of logical names,
    or "all") disables selected constraints — used by §Perf ablations.
    """
    import os
    disabled = os.environ.get("REPRO_DISABLE_CONSTRAINTS", "")
    if disabled:
        names = set(disabled.split(","))
        if "all" in names or any(n in names for n in logical if n):
            return x
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    sizes = _axis_sizes(mesh)
    dims = []
    for dim_size, name in zip(x.shape, logical):
        ax = _resolve(name, mesh.axis_names)
        if ax is None:
            dims.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = 1
        for a in axes:
            total *= sizes[a]
        dims.append(ax if dim_size % total == 0 else None)
    dims += [None] * (x.ndim - len(dims))
    return jax.lax.with_sharding_constraint(x, P(*dims))
