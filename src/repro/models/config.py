"""Model configuration — one dataclass covers all 10 assigned families."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str            # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0

    # MLA (deepseek-v2)
    mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    mla_absorb: bool = True   # absorbed decode (W_uk/W_uv folded); False
    #                           = naive per-head expansion (perf baseline)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssd_chunk: int = 128   # SSD intra-chunk length Q (the (b,nc,h,Q,Q)
    #                        decay tensor is the working-set whale)

    # hybrid (zamba2): one shared attention+MLP block applied periodically
    attn_every: int = 0

    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_frames: int = 1500
    cross_attn: bool = False

    # sparse attention (DDM planner; sparse/)
    attn_pattern: str = "full"    # full | ddm_window
    window: int = 0               # kv window size (tokens), ddm_window
    n_sink_blocks: int = 1        # global "attention sink" blocks
    block_q: int = 128
    block_kv: int = 128
    window_gather_decode: bool = False  # decode reads only the DDM
    #   window + sink from the cache (dynamic-slice gather) instead of
    #   masking the full context — §Perf beyond-paper optimization

    # numerics / structure
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: bool = True
    q_chunk: int = 128          # attention query-chunk (flash outer loop)
    ce_chunk: int = 512         # cross-entropy sequence chunk (train)
    grad_accum: int = 1         # microbatches per step (activation mem ÷ k)
    unroll_layers: bool = False  # unroll layer loops (cost-probe compiles)

    # -- derived -----------------------------------------------------------
    @property
    def d_inner(self) -> int:        # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or (self.d_inner // self.ssm_head_dim)

    @property
    def group_size(self) -> int:     # GQA group
        return self.n_heads // max(self.n_kv_heads, 1)

    def n_params(self) -> int:
        """Analytic parameter count (embedding included once)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            if self.mla:
                attn = (d * (self.kv_lora + self.rope_head_dim)
                        + self.kv_lora * self.n_heads
                        * (self.nope_head_dim + self.v_head_dim))
                if self.q_lora:
                    attn += (d * self.q_lora + self.q_lora * self.n_heads
                             * (self.nope_head_dim + self.rope_head_dim))
                else:
                    attn += d * self.n_heads * (self.nope_head_dim
                                                + self.rope_head_dim)
                attn += self.n_heads * self.v_head_dim * d
            else:
                attn = d * self.d_head * (self.n_heads + 2 * self.n_kv_heads)
                attn += self.n_heads * self.d_head * d
            mlp = 3 * d * f
            if self.family == "moe":
                moe_mlp = 3 * d * self.moe_d_ff
                shared = self.n_shared_experts * moe_mlp
                router = d * self.n_experts
                dense_l = self.first_dense_layers
                per_layer_moe = attn + self.n_experts * moe_mlp + shared \
                    + router + 2 * d
                per_layer_dense = attn + mlp + 2 * d
                return (emb + dense_l * per_layer_dense
                        + (self.n_layers - dense_l) * per_layer_moe + d)
            per_layer = attn + mlp + 2 * d
        if self.family == "ssm":
            di, ns, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
            conv_ch = di + 2 * ns
            per_layer = (d * (2 * di + 2 * ns + nh)       # in_proj
                         + conv_ch * self.conv_width      # conv
                         + nh * 2 + di                    # A_log, D, norm
                         + di * d + d)                    # out_proj + norm
            return emb + self.n_layers * per_layer + d
        if self.family == "hybrid":
            di, ns, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
            conv_ch = di + 2 * ns
            mamba_l = (d * (2 * di + 2 * ns + nh) + conv_ch * self.conv_width
                       + nh * 2 + di + di * d + d)
            attn_shared = per_layer  # one shared attn+mlp block
            return emb + self.n_layers * mamba_l + attn_shared + d
        if self.family == "audio":
            enc = self.enc_layers * per_layer
            dec_cross = self.n_layers * (d * self.d_head
                                         * (self.n_heads + 2 * self.n_kv_heads)
                                         + self.n_heads * self.d_head * d + d)
            return emb + enc + self.n_layers * per_layer + dec_cross + d
        return emb + self.n_layers * per_layer + d
