"""Attention blocks: GQA (with qk-norm / QKV-bias variants) and MLA.

One layer's worth of attention.  All functions are pure; KV caches are
explicit pytrees threaded by the caller (the stack module scans over
layers with stacked params/caches).

Memory discipline: scores are never materialized at (Sq, Skv) — the
query axis is processed in chunks under ``lax.scan`` (flash-style outer
loop), with bf16 MXU inputs and fp32 accumulation
(``preferred_element_type``).  The peak live intermediate is
(B, H, q_chunk, Skv) fp32 per chunk.

Decode path supports the DDM-planned sliding-window read (``window`` in
the config): the query attends to the sink prefix plus the last
``window`` cache positions — (start, end) intervals come from the block
planner in ``repro.sparse``, which is backed by ``core`` matching.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (apply_rope, linear, linear_init, rms_headnorm,
                     rope_angles)
from .sharding import constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# chunked scaled-dot-product core
# ---------------------------------------------------------------------------

def chunked_sdpa(q, k, v, q_pos, kv_valid_upto, *, causal: bool = True,
                 window: int = 0, sink: int = 0, q_chunk: int = 256,
                 scale: float | None = None, kv_pos=None, kv_allowed=None):
    """q: (B,Sq,H,G,dh), k: (B,Skv,H,dh), v: (B,Skv,H,dv) → (B,Sq,H,G,dv).

    ``q_pos``: (Sq,) absolute query positions.  ``kv_valid_upto``: number
    of valid cache positions (scalar).  ``window``/``sink``: DDM-planned
    sparse read [0, sink) ∪ (q_pos − window, q_pos].  ``kv_pos``: explicit
    absolute positions of the kv rows (for gathered windows); then
    ``kv_valid_upto`` applies to positions and ``kv_allowed`` (bool
    (Skv,)) masks duplicate rows.
    """
    B, Sq, H, G, dh = q.shape
    Skv = k.shape[1]
    dv = v.shape[-1]
    scale = scale if scale is not None else dh ** -0.5
    if kv_pos is None:
        kv_pos = jnp.arange(Skv)

    cq = min(q_chunk, Sq)
    pad = (-Sq) % cq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=-1)
    nchunk = q.shape[1] // cq
    qs = q.reshape(B, nchunk, cq, H, G, dh).swapaxes(0, 1)
    ps = q_pos.reshape(nchunk, cq)

    @jax.checkpoint
    def one_chunk_body(qc, pc):
        # (B,cq,H,G,dh), (cq,).  Rematted: the (B,H,G,cq,Skv) score
        # block is recomputed in backward instead of being stacked
        # across chunks as a residual (the flash-attention memory fix).
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, k,
                       preferred_element_type=jnp.float32) * scale
        s = constrain(s, "dp", "tp", None, None, None)
        ok = kv_pos[None, :] < kv_valid_upto
        if kv_allowed is not None:
            ok = ok & kv_allowed[None, :]
        if causal:
            ok = ok & (kv_pos[None, :] <= pc[:, None])
        if window > 0:
            ok = ok & ((kv_pos[None, :] > pc[:, None] - window)
                       | (kv_pos[None, :] < sink))
        s = jnp.where(ok[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p)     # fully-masked (pad) rows
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.astype(v.dtype)

    def one_chunk(_, args):
        qc, pc = args
        return _, one_chunk_body(qc, pc)

    _, outs = jax.lax.scan(one_chunk, None, (qs, ps))
    out = outs.swapaxes(0, 1).reshape(B, nchunk * cq, H, G, dv)
    return out[:, :Sq]


def _cache_write(cache: dict, new: dict, start) -> dict:
    out = dict(cache)
    for key, val in new.items():
        buf = cache[key]
        idx = (0, start) + (0,) * (buf.ndim - 2)
        out[key] = jax.lax.dynamic_update_slice(buf, val.astype(buf.dtype),
                                                idx)
    return out


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d, dh = cfg.d_model, cfg.d_head
    return {
        "wq": linear_init(ks[0], d, cfg.n_heads * dh, bias=cfg.qkv_bias),
        "wk": linear_init(ks[1], d, cfg.n_kv_heads * dh, bias=cfg.qkv_bias),
        "wv": linear_init(ks[2], d, cfg.n_kv_heads * dh, bias=cfg.qkv_bias),
        "wo": linear_init(ks[3], cfg.n_heads * dh, d,
                          std=(cfg.n_heads * dh) ** -0.5
                          / max(2 * cfg.n_layers, 1) ** 0.5),
    }


def gqa_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    dh = cfg.d_head
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype),
    }


def gqa_apply(p, x, cfg: ModelConfig, *, positions: Array,
              cache: dict | None = None, cur_len=0,
              causal: bool = True, window: int = 0, sink: int = 0):
    """One attention sublayer.  Returns (y, new_cache)."""
    B, S, _ = x.shape
    dh = cfg.d_head
    dt = x.dtype
    q = linear(p["wq"], x, dt).reshape(B, S, cfg.n_heads, dh)
    k = linear(p["wk"], x, dt).reshape(B, S, cfg.n_kv_heads, dh)
    v = linear(p["wv"], x, dt).reshape(B, S, cfg.n_kv_heads, dh)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    if cfg.qk_norm:
        q, k = rms_headnorm(q), rms_headnorm(k)
    cos, sin = rope_angles(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cache is not None:
        cache = _cache_write(cache, {"k": k, "v": v}, cur_len)
        k_all, v_all = cache["k"], cache["v"]
        valid = cur_len + S
    else:
        k_all, v_all = k, v
        valid = S
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, S, cfg.n_kv_heads, g, dh)

    if (cache is not None and S == 1 and window > 0
            and cfg.window_gather_decode):
        # --- DDM-window gather decode: materialize only the matched
        # interval [pos+1−window, pos] plus the sink prefix from the
        # cache (two dynamic slices) — HBM traffic ∝ window instead of
        # ∝ context.  The interval comes from the same planner as the
        # masked path (sparse.planner.decode_window).
        Smax = k_all.shape[1]
        W = min(window, Smax)
        pos = positions[0]
        start = jnp.clip(pos + 1 - W, 0, Smax - W)
        k_win = jax.lax.dynamic_slice_in_dim(k_all, start, W, axis=1)
        v_win = jax.lax.dynamic_slice_in_dim(v_all, start, W, axis=1)
        kv_pos_w = start + jnp.arange(W)
        if sink > 0:
            k_cat = jnp.concatenate([k_all[:, :sink], k_win], axis=1)
            v_cat = jnp.concatenate([v_all[:, :sink], v_win], axis=1)
            kv_pos_c = jnp.concatenate([jnp.arange(sink), kv_pos_w])
            # window rows overlapping the sink prefix are duplicates
            allowed = jnp.concatenate(
                [jnp.ones(sink, bool), kv_pos_w >= sink])
        else:
            k_cat, v_cat, kv_pos_c = k_win, v_win, kv_pos_w
            allowed = jnp.ones(W, bool)
        out = chunked_sdpa(qg, k_cat, v_cat, positions, valid,
                           causal=causal, q_chunk=cfg.q_chunk,
                           kv_pos=kv_pos_c, kv_allowed=allowed)
    else:
        out = chunked_sdpa(qg, k_all, v_all, positions, valid,
                           causal=causal, window=window, sink=sink,
                           q_chunk=cfg.q_chunk)
    y = linear(p["wo"], out.reshape(B, S, -1), dt)
    return constrain(y, "dp", None, None), cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent-compressed KV, decoupled RoPE key
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    nh = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    p = {
        "w_dkv": linear_init(ks[0], d, cfg.kv_lora + dr),
        "w_ukv": linear_init(ks[1], cfg.kv_lora, nh * (dn + dv)),
        "wo": linear_init(ks[2], nh * dv, d,
                          std=(nh * dv) ** -0.5
                          / max(2 * cfg.n_layers, 1) ** 0.5),
        "kv_norm": {"scale": jnp.ones((cfg.kv_lora,), jnp.float32)},
    }
    if cfg.q_lora:
        p["w_dq"] = linear_init(ks[3], d, cfg.q_lora)
        p["q_norm"] = {"scale": jnp.ones((cfg.q_lora,), jnp.float32)}
        p["w_uq"] = linear_init(ks[4], cfg.q_lora, nh * (dn + dr))
    else:
        p["wq"] = linear_init(ks[5], d, nh * (dn + dr))
    return p


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
    }


def mla_apply(p, x, cfg: ModelConfig, *, positions: Array,
              cache: dict | None = None, cur_len=0,
              causal: bool = True, window: int = 0, sink: int = 0):
    from .layers import rmsnorm

    B, S, _ = x.shape
    dt = x.dtype
    nh = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim

    # latent KV path
    dkv = linear(p["w_dkv"], x, dt)
    ckv, k_rope = dkv[..., : cfg.kv_lora], dkv[..., cfg.kv_lora:]
    ckv = rmsnorm(p["kv_norm"], ckv, cfg.norm_eps)
    cos, sin = rope_angles(positions, dr, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    if cache is not None:
        cache = _cache_write(cache, {"ckv": ckv, "krope": k_rope}, cur_len)
        ckv_all, krope_all = cache["ckv"], cache["krope"]
        valid = cur_len + S
    else:
        ckv_all, krope_all = ckv, k_rope
        valid = S

    # queries
    if cfg.q_lora:
        cq = rmsnorm(p["q_norm"], linear(p["w_dq"], x, dt), cfg.norm_eps)
        q = linear(p["w_uq"], cq, dt).reshape(B, S, nh, dn + dr)
    else:
        q = linear(p["wq"], x, dt).reshape(B, S, nh, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, cos, sin)

    if cache is not None and S == 1 and cfg.mla_absorb:
        # --- absorbed decode (DeepSeek-V2 §2.1.4 low-rank trick): fold
        # W_uk into the query and W_uv into the output so attention runs
        # directly on the (kv_lora)-dim latent cache — no per-head K/V
        # expansion over the full context.
        # f32 casts: XLA TPU fuses the converts into MXU dots; the CPU
        # backend lacks a bf16×bf16→f32 dot thunk, so keep dots in f32.
        w_ukv = p["w_ukv"]["w"].reshape(cfg.kv_lora, nh, dn + dv)
        w_uk = w_ukv[..., :dn].astype(jnp.float32)
        w_uv = w_ukv[..., dn:].astype(dt)
        q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(jnp.float32),
                           w_uk)
        s_nope = jnp.einsum("bqhl,bkl->bhqk", q_abs,
                            ckv_all.astype(jnp.float32))
        s_rope = jnp.einsum("bqhd,bkd->bhqk",
                            q_rope.astype(jnp.float32),
                            krope_all.astype(jnp.float32))
        scores = (s_nope + s_rope) * ((dn + dr) ** -0.5)
        Skv = ckv_all.shape[1]
        kv_pos = jnp.arange(Skv)
        ok = (kv_pos[None, :] < valid) & \
            (kv_pos[None, :] <= positions[:, None])
        if window > 0:
            ok = ok & ((kv_pos[None, :] > positions[:, None] - window)
                       | (kv_pos[None, :] < sink))
        scores = jnp.where(ok[None, None], scores, -jnp.inf)
        pr = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bkl->bqhl", pr,
                         ckv_all.astype(jnp.float32)).astype(dt)
        out = jnp.einsum("bqhl,lhd->bqhd", ctx, w_uv)
        y = linear(p["wo"], out.reshape(B, S, nh * dv), dt)
        return constrain(y, "dp", None, None), cache

    # expand latents to per-head K/V (training / prefill)
    ukv = linear(p["w_ukv"], ckv_all, dt)
    Skv = ukv.shape[1]
    ukv = ukv.reshape(B, Skv, nh, dn + dv)
    k_nope, vv = ukv[..., :dn], ukv[..., dn:]
    kk = jnp.concatenate(
        [k_nope,
         jnp.broadcast_to(krope_all[:, :, None, :], (B, Skv, nh, dr))],
        axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]
    qq = qq.reshape(B, S, nh, 1, dn + dr)
    qq = constrain(qq, "dp", None, "tp", None, None)

    out = chunked_sdpa(qq, kk, vv, positions, valid, causal=causal,
                       window=window, sink=sink, q_chunk=cfg.q_chunk,
                       scale=(dn + dr) ** -0.5)
    y = linear(p["wo"], out.reshape(B, S, nh * dv), dt)
    return constrain(y, "dp", None, None), cache


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig):
    return mla_init(key, cfg) if cfg.mla else gqa_init(key, cfg)


def attn_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return (mla_cache_init(cfg, batch, max_len, dtype) if cfg.mla
            else gqa_cache_init(cfg, batch, max_len, dtype))


def attn_apply(p, x, cfg: ModelConfig, **kw):
    return (mla_apply(p, x, cfg, **kw) if cfg.mla
            else gqa_apply(p, x, cfg, **kw))
