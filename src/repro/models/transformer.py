"""Model stacks for all assigned families.

Layer parameters are *stacked* along a leading L axis and consumed with
``lax.scan`` (small HLO, fast compile, natural remat boundary).  Families
with heterogeneous layers split into homogeneous stacked groups:

  dense / vlm   : [L × (attn + mlp)]
  moe           : [first_dense × (attn + mlp)] + [rest × (attn + moe)]
  ssm           : [L × mamba2]
  hybrid        : [(L/k groups) × (k × mamba2)] + one *shared* attn+mlp
                  block applied after every group (Zamba2-style weight
                  sharing; see DESIGN.md §Arch-applicability)
  audio         : encoder [Lenc × (attn + mlp, non-causal)] +
                  decoder [L × (self-attn + cross-attn + mlp)], conv
                  frontend stubbed (precomputed frame embeddings)

Public entry points (used by launch/, examples/, tests/):
  init_params(cfg, key)            — pure; jax.eval_shape-able
  loss_fn(params, batch, cfg)      — next-token CE (+ MoE aux)
  init_cache(cfg, batch, max_len)  — decode cache pytree
  prefill(params, batch, cfg, cache)   — logits + filled cache
  decode_step(params, tokens, cfg, cache, cur_len) — one token
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .attention import attn_apply, attn_cache_init, attn_init
from .config import ModelConfig
from .layers import embed, embed_init, linear, linear_init, rmsnorm, \
    rmsnorm_init, softmax_xent, truncated_normal
from .mlp import mlp_apply, mlp_init
from .moe import moe_apply, moe_init
from .ssm import mamba2_apply, mamba2_cache_init, mamba2_init
from .sharding import constrain

Array = jax.Array
PyTree = Any


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _sparse_kw(cfg: ModelConfig) -> dict:
    if cfg.attn_pattern == "ddm_window" and cfg.window > 0:
        return {"window": cfg.window,
                "sink": cfg.n_sink_blocks * cfg.block_kv}
    return {}


# ---------------------------------------------------------------------------
# homogeneous layer bodies
# ---------------------------------------------------------------------------

def _dense_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": rmsnorm_init(cfg.d_model), "attn": attn_init(k1, cfg),
            "ln2": rmsnorm_init(cfg.d_model), "mlp": mlp_init(k2, cfg)}


def _dense_layer_apply(p, x, cfg, *, positions, cache=None, cur_len=0,
                       causal=True, **sparse):
    x = constrain(x, "dp", "tpseq", None)
    a, cache = attn_apply(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                          cfg, positions=positions, cache=cache,
                          cur_len=cur_len, causal=causal, **sparse)
    x = x + a
    x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, cache


def _moe_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": rmsnorm_init(cfg.d_model), "attn": attn_init(k1, cfg),
            "ln2": rmsnorm_init(cfg.d_model), "moe": moe_init(k2, cfg)}


def _moe_layer_apply(p, x, cfg, *, positions, cache=None, cur_len=0,
                     causal=True, **sparse):
    x = constrain(x, "dp", "tpseq", None)
    a, cache = attn_apply(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                          cfg, positions=positions, cache=cache,
                          cur_len=cur_len, causal=causal, **sparse)
    x = x + a
    y, aux = moe_apply(p["moe"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    return x + y, cache, aux


def _mamba_layer_init(key, cfg: ModelConfig):
    return {"ln": rmsnorm_init(cfg.d_model), "mixer": mamba2_init(key, cfg)}


def _mamba_layer_apply(p, x, cfg, *, cache=None):
    x = constrain(x, "dp", "tpseq", None)
    y, cache = mamba2_apply(p["mixer"], rmsnorm(p["ln"], x, cfg.norm_eps),
                            cfg, cache=cache)
    return x + y, cache


def _stacked(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> PyTree:
    ks = jax.random.split(key, 8)
    p: dict = {"embed": embed_init(ks[0], cfg.vocab, cfg.d_model),
               "final_norm": rmsnorm_init(cfg.d_model)}
    if not cfg.tie_embeddings:
        p["lm_head"] = linear_init(ks[1], cfg.d_model, cfg.vocab)

    if cfg.family in ("dense", "vlm"):
        p["layers"] = _stacked(lambda k: _dense_layer_init(k, cfg),
                               ks[2], cfg.n_layers)
    elif cfg.family == "moe":
        nd = cfg.first_dense_layers
        if nd:
            p["dense_layers"] = _stacked(
                lambda k: _dense_layer_init(k, cfg), ks[2], nd)
        p["moe_layers"] = _stacked(
            lambda k: _moe_layer_init(k, cfg), ks[3], cfg.n_layers - nd)
    elif cfg.family == "ssm":
        p["layers"] = _stacked(lambda k: _mamba_layer_init(k, cfg),
                               ks[2], cfg.n_layers)
    elif cfg.family == "hybrid":
        per = cfg.attn_every
        groups = cfg.n_layers // per
        keys = jax.random.split(ks[2], groups)
        p["mamba_groups"] = jax.vmap(
            lambda k: _stacked(lambda kk: _mamba_layer_init(kk, cfg),
                               k, per))(keys)
        p["shared_block"] = _dense_layer_init(ks[3], cfg)
    elif cfg.family == "audio":
        p["enc_pos"] = truncated_normal(ks[4], (cfg.enc_frames,
                                                cfg.d_model), 0.02)
        p["enc_layers"] = _stacked(lambda k: _dense_layer_init(k, cfg),
                                   ks[2], cfg.enc_layers)
        p["dec_layers"] = _stacked(lambda k: _decoder_layer_init(k, cfg),
                                   ks[3], cfg.n_layers)
        p["enc_norm"] = rmsnorm_init(cfg.d_model)
    else:
        raise ValueError(cfg.family)
    return p


def _decoder_layer_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": rmsnorm_init(cfg.d_model), "attn": attn_init(k1, cfg),
            "lnx": rmsnorm_init(cfg.d_model), "xattn": attn_init(k2, cfg),
            "ln2": rmsnorm_init(cfg.d_model), "mlp": mlp_init(k3, cfg)}


# ---------------------------------------------------------------------------
# forward (training / prefill / decode share one path per family)
# ---------------------------------------------------------------------------

def _maybe_remat(f, cfg: ModelConfig):
    return jax.checkpoint(f) if cfg.remat else f


def _scan_layers(layer_fn, stacked_params, x, caches, cfg: ModelConfig):
    """Scan a homogeneous stacked group. layer_fn(p, x, cache) ->
    (x, cache, aux)."""
    if cfg.unroll_layers:
        # cost-probe mode: XLA cost_analysis counts a while-loop body
        # once regardless of trip count, so probes unroll (launch/dryrun)
        n = jax.tree.leaves(stacked_params)[0].shape[0]
        aux = jnp.float32(0.0)
        new_caches = []
        for i in range(n):
            pl = jax.tree.map(lambda a: a[i], stacked_params)
            cl = None if caches is None else jax.tree.map(
                lambda a: a[i], caches)
            x, cl, a = layer_fn(pl, x, cl)
            aux = aux + a
            new_caches.append(cl)
        if caches is None:
            return x, None, aux
        return x, jax.tree.map(lambda *a: jnp.stack(a), *new_caches), aux
    if caches is None:
        def body(carry, pl):
            xx, aux = carry
            xx, _, a = layer_fn(pl, xx, None)
            return (xx, aux + a), None

        (x, aux), _ = jax.lax.scan(_maybe_remat(body, cfg),
                                   (x, jnp.float32(0.0)), stacked_params)
        return x, None, aux

    def body(carry, xs):
        xx, aux = carry
        pl, cl = xs
        xx, cl, a = layer_fn(pl, xx, cl)
        return (xx, aux + a), cl

    (x, aux), new_caches = jax.lax.scan(
        _maybe_remat(body, cfg), (x, jnp.float32(0.0)),
        (stacked_params, caches))
    return x, new_caches, aux


def forward(params, tokens, cfg: ModelConfig, *, caches=None, cur_len=0,
            frames=None, return_features=False):
    """Logits for a token slab.  tokens: (B, S) int32.

    ``caches``: None (training) or the cache pytree (prefill/decode —
    written at [cur_len, cur_len+S)).  ``frames``: (B, F, d) precomputed
    frame/patch embeddings for the audio/vlm frontends (stub).
    ``return_features``: skip the LM head (training uses chunked CE).
    Returns (logits_f32 (B,S,vocab) | features, new_caches, aux_loss).
    """
    dt = _dtype(cfg)
    B, S = tokens.shape
    x = constrain(embed(params["embed"], tokens, dt), "dp", None, None)
    positions = cur_len + jnp.arange(S)
    sparse = _sparse_kw(cfg)
    aux_total = jnp.float32(0.0)
    new_caches = {} if caches is not None else None

    def attach(name, val):
        if new_caches is not None:
            new_caches[name] = val

    if cfg.family in ("dense", "vlm"):
        def lf(p, x, c):
            x, c = _dense_layer_apply(p, x, cfg, positions=positions,
                                      cache=c, cur_len=cur_len, **sparse)
            return x, c, jnp.float32(0.0)
        x, nc, aux = _scan_layers(
            lf, params["layers"], x,
            None if caches is None else caches["layers"], cfg)
        aux_total += aux
        attach("layers", nc)

    elif cfg.family == "moe":
        if cfg.first_dense_layers:
            def lfd(p, x, c):
                x, c = _dense_layer_apply(p, x, cfg, positions=positions,
                                          cache=c, cur_len=cur_len,
                                          **sparse)
                return x, c, jnp.float32(0.0)
            x, nc, _ = _scan_layers(
                lfd, params["dense_layers"], x,
                None if caches is None else caches["dense_layers"], cfg)
            attach("dense_layers", nc)

        def lfm(p, x, c):
            x, c, aux = _moe_layer_apply(p, x, cfg, positions=positions,
                                         cache=c, cur_len=cur_len, **sparse)
            return x, c, aux
        x, nc, aux = _scan_layers(
            lfm, params["moe_layers"], x,
            None if caches is None else caches["moe_layers"], cfg)
        aux_total += aux
        attach("moe_layers", nc)

    elif cfg.family == "ssm":
        def lf(p, x, c):
            x, c = _mamba_layer_apply(p, x, cfg, cache=c)
            return x, c, jnp.float32(0.0)
        x, nc, _ = _scan_layers(
            lf, params["layers"], x,
            None if caches is None else caches["layers"], cfg)
        attach("layers", nc)

    elif cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        mg = params["mamba_groups"]
        mcaches = None if caches is None else caches["mamba_groups"]
        acaches = None if caches is None else caches["attn"]
        new_m, new_a = [], []
        for g in range(groups):
            gp = jax.tree.map(lambda a: a[g], mg)
            gc = None if mcaches is None else jax.tree.map(
                lambda a: a[g], mcaches)

            def lf(p, x, c):
                x, c = _mamba_layer_apply(p, x, cfg, cache=c)
                return x, c, jnp.float32(0.0)
            x, nc, _ = _scan_layers(lf, gp, x, gc, cfg)
            ac = None if acaches is None else jax.tree.map(
                lambda a: a[g], acaches)
            shared_apply = _maybe_remat(
                lambda pp, xx, cc: _dense_layer_apply(
                    pp, xx, cfg, positions=positions, cache=cc,
                    cur_len=cur_len, **sparse), cfg)
            x, ac = shared_apply(params["shared_block"], x, ac)
            new_m.append(nc)
            new_a.append(ac)
        if caches is not None:
            attach("mamba_groups",
                   jax.tree.map(lambda *a: jnp.stack(a), *new_m))
            attach("attn", jax.tree.map(lambda *a: jnp.stack(a), *new_a))

    elif cfg.family == "audio":
        # frames present => run the encoder (training / prefill);
        # frames absent  => reuse the cached encoder output (decode).
        if frames is not None:
            F = frames.shape[1]
            enc = frames.astype(dt) + params["enc_pos"][None, :F].astype(dt)
            enc_pos = jnp.arange(F)

            def ef(p, x, c):
                x, _ = _dense_layer_apply(p, x, cfg, positions=enc_pos,
                                          cache=None, causal=False)
                return x, c, jnp.float32(0.0)
            enc, _, _ = _scan_layers(ef, params["enc_layers"], enc,
                                     None, cfg)
            enc = rmsnorm(params["enc_norm"], enc, cfg.norm_eps)
        else:
            assert caches is not None and "enc_out" in caches, \
                "audio decode needs a prefed encoder cache"
            enc = caches["enc_out"].astype(dt)
        attach("enc_out", enc.astype(dt))

        def df(p, x, c):
            a, c = attn_apply(p["attn"],
                              rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
                              positions=positions, cache=c,
                              cur_len=cur_len, **sparse)
            x = x + a
            xq = rmsnorm(p["lnx"], x, cfg.norm_eps)
            a2, _ = _cross_attn(p["xattn"], xq, enc, cfg)
            x = x + a2
            x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))
            return x, c, jnp.float32(0.0)
        x, nc, _ = _scan_layers(
            df, params["dec_layers"], x,
            None if caches is None else caches["dec_layers"], cfg)
        attach("dec_layers", nc)
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_features:
        return x, new_caches, aux_total
    logits = _project_logits(params, x, cfg)
    return logits, new_caches, aux_total


def _project_logits(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        logits = x.astype(jnp.float32) @ params["embed"]["table"].T.astype(
            jnp.float32)
    else:
        logits = linear(params["lm_head"], x, jnp.float32)
    return constrain(logits.astype(jnp.float32), "dp", None, "tp")


def _cross_attn(p, xq, enc, cfg: ModelConfig):
    """Cross attention: queries from decoder, K/V from encoder output."""
    B, S, _ = xq.shape
    F = enc.shape[1]
    dh = cfg.d_head
    dt = xq.dtype
    from .layers import linear as lin
    q = lin(p["wq"], xq, dt).reshape(B, S, cfg.n_heads, dh)
    k = lin(p["wk"], enc, dt).reshape(B, F, cfg.n_kv_heads, dh)
    v = lin(p["wv"], enc, dt).reshape(B, F, cfg.n_kv_heads, dh)
    g = cfg.n_heads // cfg.n_kv_heads
    from .attention import chunked_sdpa
    out = chunked_sdpa(q.reshape(B, S, cfg.n_kv_heads, g, dh), k, v,
                       jnp.arange(S), F, causal=False,
                       q_chunk=cfg.q_chunk)
    return lin(p["wo"], out.reshape(B, S, -1), dt), None


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    dt = _dtype(cfg)

    def attn_stack(n):
        one = attn_cache_init(cfg, batch, max_len, dt)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), one)

    def mamba_stack(n):
        one = mamba2_cache_init(cfg, batch, dt)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), one)

    if cfg.family in ("dense", "vlm"):
        return {"layers": attn_stack(cfg.n_layers)}
    if cfg.family == "moe":
        c = {"moe_layers": attn_stack(cfg.n_layers
                                      - cfg.first_dense_layers)}
        if cfg.first_dense_layers:
            c["dense_layers"] = attn_stack(cfg.first_dense_layers)
        return c
    if cfg.family == "ssm":
        return {"layers": mamba_stack(cfg.n_layers)}
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        per = cfg.attn_every
        one = mamba2_cache_init(cfg, batch, dt)
        mg = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None, None], (groups, per) + a.shape).copy(), one)
        return {"mamba_groups": mg, "attn": attn_stack(groups)}
    if cfg.family == "audio":
        return {"dec_layers": attn_stack(cfg.n_layers),
                "enc_out": jnp.zeros((batch, cfg.enc_frames, cfg.d_model),
                                     dt)}
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def loss_fn(params, batch, cfg: ModelConfig):
    """batch: {"tokens": (B, S+1)} (+ "frames" for audio).

    Cross entropy runs in sequence chunks (``cfg.ce_chunk``) under remat
    so the (B, S, vocab) fp32 logits are never alive at once — the
    vocabulary projection dominates activation memory otherwise.
    """
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    feats, _, aux = forward(params, inputs, cfg,
                            frames=batch.get("frames"),
                            return_features=True)
    B, S, d = feats.shape
    C = min(cfg.ce_chunk, S)
    pad = (-S) % C
    if pad:
        feats = jnp.pad(feats, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nch = feats.shape[1] // C
    fc = feats.reshape(B, nch, C, d).swapaxes(0, 1)
    lc = labels.reshape(B, nch, C).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(xc, yc):
        logits = _project_logits(params, xc, cfg)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(yc, 0)[..., None], axis=-1)[..., 0]
        valid = (yc >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * valid), jnp.sum(valid)

    def body(carry, xs):
        tot, cnt = carry
        t, c = chunk_loss(*xs)
        return (tot + t, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (fc, lc))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}


def prefill(params, tokens, cfg: ModelConfig, cache, frames=None):
    logits, cache, _ = forward(params, tokens, cfg, caches=cache,
                               cur_len=0, frames=frames)
    return logits[:, -1], cache


def decode_step(params, tokens, cfg: ModelConfig, cache, cur_len,
                frames=None):
    """tokens: (B, 1); cur_len: scalar int32 — current cache fill."""
    logits, cache, _ = forward(params, tokens, cfg, caches=cache,
                               cur_len=cur_len, frames=frames)
    return logits[:, -1], cache
