"""Multi-device Parallel SBM — paper Alg. 6/7 mapped onto a JAX mesh.

The paper sketches the distributed-memory version in §4: a distributed
sort, then the prefix computation "based on the Scatter/Gather pattern".
Here that becomes, under ``shard_map`` over a 1-D device axis:

  step ⓪  **distributed sample-style sort**: endpoints are bucketed by
          value-range splitters and exchanged with one ``all_to_all``
          (the Scatter), then each device lex-sorts its value-range
          segment locally — the bucket sort the paper cites (Solomonik &
          Kalé [57]).  XLA collectives need static shapes, so every
          (src, dst) lane carries ``cap`` slots plus a validity mask;
          overflow is detected and surfaced.
  step ①  local masked scans of active-count deltas (the counting image
          of Sadd/Sdel/Uadd/Udel, Alg. 7 lines 1-17);
  step ②  the "master" exclusive combine (Alg. 7 lines 18-21) becomes an
          ``all_gather`` of two per-device scalars + a masked sum — the
          collective prefix the paper predicts stays competitive "on
          future generations of processors with a higher number of
          cores";
  step ③  seeded local sweeps; per-device partial K returned sharded,
          summed exactly on host in int64.

The same decomposition lowers at any mesh size — the multi-pod dry-run
compiles it across 512 devices.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .regions import Regions

# ``jax.shard_map`` is the new-JAX spelling; older versions ship it under
# jax.experimental with the same signature.
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - exercised only on old JAX
    from jax.experimental.shard_map import shard_map as _shard_map

Array = jax.Array
AXIS = "shards"


def _endpoints_flat(S: Regions, U: Regions):
    """Unsorted endpoint stream (v, is_lo, is_upd) — host order."""
    n, m = S.n, U.n
    v = jnp.concatenate([S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0]])
    is_lo = jnp.concatenate([jnp.ones(n, jnp.int32), jnp.zeros(n, jnp.int32),
                             jnp.ones(m, jnp.int32), jnp.zeros(m, jnp.int32)])
    is_upd = jnp.concatenate([jnp.zeros(2 * n, jnp.int32),
                              jnp.ones(2 * m, jnp.int32)])
    return v, is_lo, is_upd


def _shard_body(v, is_lo, is_upd, valid, splitters, *, cap: int,
                nshards: int):
    """Per-device body under shard_map; all array args are local shards."""
    me = jax.lax.axis_index(AXIS)

    # -- step ⓪a: bucket by splitters, build (P, cap) send buffers --------
    bucket = jnp.searchsorted(splitters, v, side="right").astype(jnp.int32)
    bucket = jnp.where(valid > 0, bucket, nshards - 1)
    order = jnp.argsort(bucket, stable=True)
    b_sorted = bucket[order]
    starts = jnp.searchsorted(b_sorted, jnp.arange(nshards, dtype=jnp.int32),
                              side="left")
    rank = jnp.arange(b_sorted.shape[0], dtype=jnp.int32) - starts[b_sorted]
    overflow = jnp.any((rank >= cap) & (valid[order] > 0)).astype(jnp.int32)
    ok = rank < cap
    dst_b = jnp.where(ok, b_sorted, nshards)       # OOB => dropped
    dst_r = jnp.where(ok, rank, cap)

    def send_buf(x, fill):
        buf = jnp.full((nshards, cap), fill, x.dtype)
        return buf.at[dst_b, dst_r].set(x[order], mode="drop")

    sv = send_buf(v, jnp.inf)
    slo = send_buf(is_lo, 0)
    supd = send_buf(is_upd, 0)
    sval = send_buf(valid, 0)

    # -- step ⓪b: the Scatter — one all_to_all over the mesh --------------
    def xchg(x):
        return jax.lax.all_to_all(x, AXIS, split_axis=0,
                                  concat_axis=0).reshape(-1)

    rv, rlo, rupd, rval = xchg(sv), xchg(slo), xchg(supd), xchg(sval)

    # -- step ⓪c: local lex-sort of this device's value-range segment -----
    loc = jnp.lexsort((rlo, rv))        # v asc, hi-before-lo at ties
    flag_lo = rlo[loc]
    flag_upd = rupd[loc]
    val = rval[loc]
    lo_m = flag_lo * val                # masked endpoint indicators
    hi_m = (1 - flag_lo) * val
    sub_f = 1 - flag_upd

    # -- step ①: local delta scans ----------------------------------------
    d_upd = flag_upd * (lo_m - hi_m)
    d_sub = sub_f * (lo_m - hi_m)
    upd_local = jnp.cumsum(d_upd)
    sub_local = jnp.cumsum(d_sub)

    # -- step ②: exclusive combine across devices -------------------------
    totals = jnp.stack([upd_local[-1], sub_local[-1]])
    all_tot = jax.lax.all_gather(totals, AXIS)          # (P, 2)
    mask = (jnp.arange(nshards) < me)[:, None]
    carry = jnp.sum(all_tot * mask, axis=0)
    upd_active = upd_local + carry[0]
    sub_active = sub_local + carry[1]

    # -- step ③: seeded local sweep ----------------------------------------
    contrib = hi_m * (sub_f * upd_active + flag_upd * sub_active)
    part = jnp.sum(contrib, dtype=jnp.int32)
    return part[None], overflow[None]


@partial(jax.jit, static_argnames=("nshards", "cap", "mesh"))
def _dist_count(v, is_lo, is_upd, valid, splitters, *, nshards: int,
                cap: int, mesh: Mesh):
    f = _shard_map(
        partial(_shard_body, cap=cap, nshards=nshards),
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P()),
        out_specs=(P(AXIS), P(AXIS)),
    )
    return f(v, is_lo, is_upd, valid, splitters)


def distributed_sbm_count(S: Regions, U: Regions, mesh: Mesh | None = None,
                          overprovision: float = 2.5) -> int:
    """Deprecated: use the engine's ``distributed`` backend instead::

        plan = build_plan(MatchSpec(algo="sbm", backend="distributed",
                                    mesh=mesh), S.n, U.n, S.d)
        k = plan.count(S, U)
    """
    import warnings

    warnings.warn(
        "distributed_sbm_count is deprecated; use "
        "build_plan(MatchSpec(backend='distributed'), ...).count(S, U)",
        DeprecationWarning, stacklevel=2)
    from .engine import MatchSpec, build_plan
    spec = MatchSpec(algo="sbm", backend="distributed", mesh=mesh,
                     overprovision=overprovision)
    return build_plan(spec, S.n, U.n, S.d).count(S, U)


def _distributed_count(S: Regions, U: Regions, mesh: Mesh | None = None,
                       overprovision: float = 2.5) -> int:
    """Total K via multi-device parallel SBM (1-D regions).

    ``mesh``: 1-D mesh over axis "shards"; defaults to all local devices.
    Raises ``OverflowError`` if a bucket exceeds its static capacity
    (raise ``overprovision`` — cf. sample-sort splitter quality).
    """
    assert S.d == 1
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (AXIS,))
    nshards = int(np.prod(mesh.devices.shape))
    v, is_lo, is_upd = _endpoints_flat(S, U)
    tot = v.shape[0]
    pad = (-tot) % nshards
    v = jnp.pad(v, (0, pad), constant_values=jnp.inf)
    is_lo = jnp.pad(is_lo, (0, pad), constant_values=0)
    is_upd = jnp.pad(is_upd, (0, pad), constant_values=0)
    valid = jnp.pad(jnp.ones(tot, jnp.int32), (0, pad), constant_values=0)

    # value-range splitters from sample quantiles (sample sort)
    sample = np.asarray(v[: min(tot, 65536)])
    sample = sample[np.isfinite(sample)]
    if nshards > 1 and sample.size:
        qs = np.quantile(sample, np.linspace(0, 1, nshards + 1)[1:-1])
    else:
        qs = np.zeros((0,))
    splitters = jnp.asarray(qs.astype(np.float32))

    per_dev = (tot + pad) // nshards
    cap = int(per_dev * overprovision / nshards) + 16
    parts, overflow = _dist_count(v, is_lo, is_upd, valid, splitters,
                                  nshards=nshards, cap=cap, mesh=mesh)
    if int(np.max(np.asarray(overflow))) > 0:
        raise OverflowError(
            "distributed SBM bucket overflow; raise overprovision")
    return int(np.sum(np.asarray(parts), dtype=np.int64))
