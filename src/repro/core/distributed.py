"""Multi-device Parallel SBM — paper Alg. 6/7 mapped onto a JAX mesh.

The paper sketches the distributed-memory version in §4: a distributed
sort, then the prefix computation "based on the Scatter/Gather pattern".
Here that becomes, under ``shard_map`` over a 1-D device axis:

  step ⓪  **distributed sample-style sort**: endpoints are bucketed by
          value-range splitters and exchanged with one ``all_to_all``
          (the Scatter), then each device lex-sorts its value-range
          segment locally — the bucket sort the paper cites (Solomonik &
          Kalé [57]).  XLA collectives need static shapes, so every
          (src, dst) lane carries ``cap`` slots plus a validity mask;
          overflow is detected and surfaced.
  step ①  local masked scans of active-count deltas (the counting image
          of Sadd/Sdel/Uadd/Udel, Alg. 7 lines 1-17);
  step ②  the "master" exclusive combine (Alg. 7 lines 18-21) becomes an
          ``all_gather`` of two per-device scalars + a masked sum — the
          collective prefix the paper predicts stays competitive "on
          future generations of processors with a higher number of
          cores";
  step ③  seeded local sweeps; per-device partial K returned sharded,
          summed exactly on host in int64.

The same decomposition lowers at any mesh size — the multi-pod dry-run
compiles it across 512 devices.

Beyond counting, this module shards the engine's other two execution
paths (reached via ``MatchSpec(backend="distributed")``):

* **Pair enumeration** (``_dist_pairs``) distributes the exact two-pass
  count-then-emit: the n+m *emitters* (class A: one per subscription;
  class B: one per update — see ``sbm._twopass_phase1``) are split into
  per-device contiguous chunks.  Each device computes its emitters'
  exact counts with searchsorted against the replicated lo-sorted
  streams, a local inclusive scan plus one ``all_gather`` of per-device
  totals yields the *global* exclusive slot offsets, and every device
  then emits its pairs fully in parallel into its slot range of a
  globally indexed pair buffer (disjoint scatter + ``psum`` — the
  Gather).  d > 1 is handled the same way as the local path, by
  sweeping dimension 0 and filtering full d-dimensional overlap at emit
  time (invalid slots stay holes; the engine recompacts).

* **Batched dynamic-service queries** (``_dist_query_counts`` /
  ``_dist_query``) shard the query batch over the mesh while the
  interval tree and opposite-kind coordinates stay replicated — the
  queries are embarrassingly parallel (paper Alg. 5 line 10), so a
  device simply runs the vmapped verified tree walk on its row chunk.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import itm
from .regions import Regions

# ``jax.shard_map`` is the new-JAX spelling; older versions ship it under
# jax.experimental with the same signature.
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - exercised only on old JAX
    from jax.experimental.shard_map import shard_map as _shard_map

Array = jax.Array
AXIS = "shards"


def resolve_mesh(mesh: Mesh | None) -> Mesh:
    """The spec's mesh, or a 1-D mesh over all local devices."""
    if mesh is None:
        return Mesh(np.array(jax.devices()), (AXIS,))
    return mesh


def _endpoints_flat(S: Regions, U: Regions):
    """Unsorted endpoint stream (v, is_lo, is_upd) — host order."""
    n, m = S.n, U.n
    v = jnp.concatenate([S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0]])
    is_lo = jnp.concatenate([jnp.ones(n, jnp.int32), jnp.zeros(n, jnp.int32),
                             jnp.ones(m, jnp.int32), jnp.zeros(m, jnp.int32)])
    is_upd = jnp.concatenate([jnp.zeros(2 * n, jnp.int32),
                              jnp.ones(2 * m, jnp.int32)])
    return v, is_lo, is_upd


def _shard_body(v, is_lo, is_upd, valid, splitters, *, cap: int,
                nshards: int):
    """Per-device body under shard_map; all array args are local shards."""
    me = jax.lax.axis_index(AXIS)

    # -- step ⓪a: bucket by splitters, build (P, cap) send buffers --------
    bucket = jnp.searchsorted(splitters, v, side="right").astype(jnp.int32)
    bucket = jnp.where(valid > 0, bucket, nshards - 1)
    order = jnp.argsort(bucket, stable=True)
    b_sorted = bucket[order]
    starts = jnp.searchsorted(b_sorted, jnp.arange(nshards, dtype=jnp.int32),
                              side="left")
    rank = jnp.arange(b_sorted.shape[0], dtype=jnp.int32) - starts[b_sorted]
    overflow = jnp.any((rank >= cap) & (valid[order] > 0)).astype(jnp.int32)
    ok = rank < cap
    dst_b = jnp.where(ok, b_sorted, nshards)       # OOB => dropped
    dst_r = jnp.where(ok, rank, cap)

    def send_buf(x, fill):
        buf = jnp.full((nshards, cap), fill, x.dtype)
        return buf.at[dst_b, dst_r].set(x[order], mode="drop")

    sv = send_buf(v, jnp.inf)
    slo = send_buf(is_lo, 0)
    supd = send_buf(is_upd, 0)
    sval = send_buf(valid, 0)

    # -- step ⓪b: the Scatter — one all_to_all over the mesh --------------
    def xchg(x):
        return jax.lax.all_to_all(x, AXIS, split_axis=0,
                                  concat_axis=0).reshape(-1)

    rv, rlo, rupd, rval = xchg(sv), xchg(slo), xchg(supd), xchg(sval)

    # -- step ⓪c: local lex-sort of this device's value-range segment -----
    loc = jnp.lexsort((rlo, rv))        # v asc, hi-before-lo at ties
    flag_lo = rlo[loc]
    flag_upd = rupd[loc]
    val = rval[loc]
    lo_m = flag_lo * val                # masked endpoint indicators
    hi_m = (1 - flag_lo) * val
    sub_f = 1 - flag_upd

    # -- step ①: local delta scans ----------------------------------------
    d_upd = flag_upd * (lo_m - hi_m)
    d_sub = sub_f * (lo_m - hi_m)
    upd_local = jnp.cumsum(d_upd)
    sub_local = jnp.cumsum(d_sub)

    # -- step ②: exclusive combine across devices -------------------------
    totals = jnp.stack([upd_local[-1], sub_local[-1]])
    all_tot = jax.lax.all_gather(totals, AXIS)          # (P, 2)
    mask = (jnp.arange(nshards) < me)[:, None]
    carry = jnp.sum(all_tot * mask, axis=0)
    upd_active = upd_local + carry[0]
    sub_active = sub_local + carry[1]

    # -- step ③: seeded local sweep ----------------------------------------
    contrib = hi_m * (sub_f * upd_active + flag_upd * sub_active)
    part = jnp.sum(contrib, dtype=jnp.int32)
    return part[None], overflow[None]


@partial(jax.jit, static_argnames=("nshards", "cap", "mesh"))
def _dist_count(v, is_lo, is_upd, valid, splitters, *, nshards: int,
                cap: int, mesh: Mesh):
    f = _shard_map(
        partial(_shard_body, cap=cap, nshards=nshards),
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P()),
        out_specs=(P(AXIS), P(AXIS)),
    )
    return f(v, is_lo, is_upd, valid, splitters)


def _distributed_count(S: Regions, U: Regions, mesh: Mesh | None = None,
                       overprovision: float = 2.5) -> int:
    """Total K via multi-device parallel SBM (1-D regions).

    ``mesh``: 1-D mesh over axis "shards"; defaults to all local devices.
    Raises ``OverflowError`` if a bucket exceeds its static capacity
    (raise ``overprovision`` — cf. sample-sort splitter quality).
    """
    assert S.d == 1
    mesh = resolve_mesh(mesh)
    nshards = int(np.prod(mesh.devices.shape))
    v, is_lo, is_upd = _endpoints_flat(S, U)
    tot = v.shape[0]
    pad = (-tot) % nshards
    v = jnp.pad(v, (0, pad), constant_values=jnp.inf)
    is_lo = jnp.pad(is_lo, (0, pad), constant_values=0)
    is_upd = jnp.pad(is_upd, (0, pad), constant_values=0)
    valid = jnp.pad(jnp.ones(tot, jnp.int32), (0, pad), constant_values=0)

    # value-range splitters from sample quantiles (sample sort)
    sample = np.asarray(v[: min(tot, 65536)])
    sample = sample[np.isfinite(sample)]
    if nshards > 1 and sample.size:
        qs = np.quantile(sample, np.linspace(0, 1, nshards + 1)[1:-1])
    else:
        qs = np.zeros((0,))
    splitters = jnp.asarray(qs.astype(np.float32))

    per_dev = (tot + pad) // nshards
    cap = int(per_dev * overprovision / nshards) + 16
    parts, overflow = _dist_count(v, is_lo, is_upd, valid, splitters,
                                  nshards=nshards, cap=cap, mesh=mesh)
    if int(np.max(np.asarray(overflow))) > 0:
        raise OverflowError(
            "distributed SBM bucket overflow; raise overprovision")
    return int(np.sum(np.asarray(parts), dtype=np.int64))


# ---------------------------------------------------------------------------
# Distributed two-pass pair enumeration — sharded count-then-emit
# ---------------------------------------------------------------------------

def _pairs_body(emit_lo, emit_hi, u_lo_sorted, s_lo_sorted, perm_s, perm_u,
                S_lo, S_hi, U_lo, U_hi, *, cap: int, nshards: int):
    """Per-device emit body: this device's emitter chunk → its slot range.

    ``emit_lo``/``emit_hi`` are the local chunk of the n+m emitter
    intervals (dim 0); everything else is replicated.  Returns the
    globally indexed pair buffer (psum-combined; slot values are the
    pair indices + 1, 0 meaning "empty"), the per-emitter exact counts
    (sharded — the host sums them in int64 for the exact K, exactly as
    the local path does), and the per-device verified-pair total.

    Slot offsets saturate at ``cap`` (the same convention as the local
    ``_twopass_phase1`` scan), so slot arithmetic stays in int32 even
    when the true K exceeds the buffer — truncation never corrupts the
    emitted prefix.  Note the emit loop scans the full global ``cap``
    per device (O(P·K) work and an O(cap) psum): correct at any mesh
    size, but the emit stage itself does not get faster with P — see
    the ROADMAP follow-up on per-device slot-bound emission.
    """
    me = jax.lax.axis_index(AXIS)
    n, m = S_lo.shape[0], U_lo.shape[0]
    chunk = emit_lo.shape[0]
    gid = me * chunk + jnp.arange(chunk, dtype=jnp.int32)
    alive = gid < (n + m)          # padding emitters contribute nothing
    is_b = gid >= n                # class B: one emitter per update

    # per-device exact counts (pass 1): both classes are searchsorted
    # ranges over the replicated lo-sorted streams (sbm._twopass_phase1)
    aA = jnp.searchsorted(u_lo_sorted, emit_lo, side="left")
    rA = jnp.searchsorted(u_lo_sorted, emit_hi, side="left")
    bB = jnp.searchsorted(s_lo_sorted, emit_lo, side="right")
    cB = jnp.searchsorted(s_lo_sorted, emit_hi, side="left")
    start = jnp.where(is_b, bB, aA).astype(jnp.int32)
    end = jnp.where(is_b, cB, rA).astype(jnp.int32)
    cnt = jnp.where(alive, jnp.maximum(end - start, 0), 0)

    # local saturating scan + one all_gather = global exclusive offsets
    # (saturation keeps every offset ≤ cap, so int32 never wraps)
    lim = jnp.int32(cap)
    sat = lambda a, b: jnp.minimum(a + b, lim)            # noqa: E731
    incl = jax.lax.associative_scan(sat, cnt)
    total = incl[-1]
    loffs = jnp.concatenate([jnp.zeros((1,), jnp.int32), incl])
    all_tot = jax.lax.all_gather(total[None], AXIS).reshape(-1)
    cums = jax.lax.associative_scan(sat, all_tot)
    excl = jnp.concatenate([jnp.zeros((1,), jnp.int32), cums[:-1]])
    carry = excl[me]

    # fully parallel per-device emit into global slots [carry, carry+T)
    j = jnp.arange(cap, dtype=jnp.int32)
    e = jnp.clip(jnp.searchsorted(loffs, j, side="right").astype(jnp.int32)
                 - 1, 0, chunk - 1)
    rank = j - loffs[e]
    kidx = start[e] + rank
    eb = is_b[e]
    s_idx = jnp.where(eb, perm_s[jnp.clip(kidx, 0, n - 1)],
                      jnp.clip(gid[e], 0, n - 1))
    u_idx = jnp.where(eb, jnp.clip(gid[e] - n, 0, m - 1),
                      perm_u[jnp.clip(kidx, 0, m - 1)])
    in_stream = j < total
    # emit-time d-dim filter on dims 1..d-1 (vacuously true at d == 1)
    ok_d = jnp.all(jnp.logical_and(S_lo[s_idx, 1:] < U_hi[u_idx, 1:],
                                   U_lo[u_idx, 1:] < S_hi[s_idx, 1:]),
                   axis=-1)
    ver = jnp.sum(in_stream & ok_d, dtype=jnp.int32)
    g = carry + j
    put = in_stream & ok_d & (g < cap)
    slot = jnp.where(put, g, cap)              # OOB => dropped
    buf = jnp.zeros((cap, 2), jnp.int32).at[slot].set(
        jnp.stack([s_idx, u_idx], axis=1) + 1, mode="drop")
    buf = jax.lax.psum(buf, AXIS)              # slot ranges are disjoint
    return buf, cnt, ver[None]


def _dist_pairs(S_lo, S_hi, U_lo, U_hi, *, cap: int, nshards: int,
                mesh: Mesh):
    """Sharded exact two-pass pair enumeration (jit via the caller).

    Returns ``(pairs, counts, ver_totals)``: ``pairs`` is the (cap, 2)
    −1-padded global buffer (dim-0 emission order; for d > 1 slots
    whose pair fails the full overlap check are −1 holes), ``counts``
    the per-emitter exact dim-0 counts (n+m padded, int32 — the host
    sums them in int64 for the exact K, which may exceed both the
    buffer and int32), and ``ver_totals`` the (nshards,) per-device
    verified-pair partials.
    """
    n, m = S_lo.shape[0], U_lo.shape[0]
    s_lo0, u_lo0 = S_lo[:, 0], U_lo[:, 0]
    perm_s = jnp.argsort(s_lo0).astype(jnp.int32)
    perm_u = jnp.argsort(u_lo0).astype(jnp.int32)
    s_sorted = s_lo0[perm_s]
    u_sorted = u_lo0[perm_u]
    emit_lo = jnp.concatenate([s_lo0, u_lo0])
    emit_hi = jnp.concatenate([S_hi[:, 0], U_hi[:, 0]])
    pad = (-(n + m)) % nshards
    if pad:
        emit_lo = jnp.pad(emit_lo, (0, pad))
        emit_hi = jnp.pad(emit_hi, (0, pad))
    f = _shard_map(
        partial(_pairs_body, cap=cap, nshards=nshards),
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(), P(), P(), P(),
                  P(), P(), P(), P()),
        out_specs=(P(), P(AXIS), P(AXIS)),
    )
    buf, counts, ver_tot = f(emit_lo, emit_hi, u_sorted, s_sorted,
                             perm_s, perm_u, S_lo, S_hi, U_lo, U_hi)
    pairs = jnp.where(buf[:, :1] > 0, buf - 1, -1)
    return pairs, counts, ver_tot


# ---------------------------------------------------------------------------
# Distributed batched dynamic-service queries — tree replicated, queries
# sharded (embarrassingly parallel, paper Alg. 5 line 10)
# ---------------------------------------------------------------------------

def _shard_map_norep(f, *, mesh, in_specs, out_specs):
    """shard_map without the replication checker: the vmapped tree walks
    are ``while_loop``s, for which check_rep has no rule (outputs here
    are all row-sharded, so nothing is lost).  Newer JAX drops the
    kwarg — fall back to the plain call there."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover - future-JAX spelling
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)


def _query_counts_body(tree, q_lo0, q_hi0):
    return itm.itm_query_counts(tree, q_lo0, q_hi0)


def _dist_query_counts(tree, q_lo0, q_hi0, *, nshards: int, mesh: Mesh):
    """Per-query dim-0 candidate counts, query rows sharded over the mesh.

    The host reduces the gathered counts to the global max — that single
    reduction is what sizes the shared query capacity under ``grow``.
    """
    b = q_lo0.shape[0]
    pad = (-b) % nshards
    if pad:
        # impossible boxes: pruned at the root, zero candidates
        q_lo0 = jnp.pad(q_lo0, (0, pad), constant_values=jnp.inf)
        q_hi0 = jnp.pad(q_hi0, (0, pad), constant_values=-jnp.inf)
    f = _shard_map_norep(_query_counts_body, mesh=mesh,
                         in_specs=(P(), P(AXIS), P(AXIS)),
                         out_specs=P(AXIS))
    return f(tree, q_lo0, q_hi0)[:b]


def _query_body(tree, o_lo, o_hi, q_lo, q_hi, *, cap: int):
    return itm.itm_query_pairs_dd(tree, o_lo, o_hi, q_lo, q_hi, cap=cap)


def _dist_query(tree, o_lo, o_hi, q_lo, q_hi, *, cap: int, nshards: int,
                mesh: Mesh):
    """Sharded verified d-dim batched query (engine ``plan.query`` path)."""
    b = q_lo.shape[0]
    pad = (-b) % nshards
    if pad:
        q_lo = jnp.pad(q_lo, ((0, pad), (0, 0)), constant_values=jnp.inf)
        q_hi = jnp.pad(q_hi, ((0, pad), (0, 0)), constant_values=-jnp.inf)
    f = _shard_map_norep(partial(_query_body, cap=cap), mesh=mesh,
                         in_specs=(P(), P(), P(), P(AXIS), P(AXIS)),
                         out_specs=(P(AXIS), P(AXIS)))
    ids, cnt = f(tree, o_lo, o_hi, q_lo, q_hi)
    return ids[:b], cnt[:b]
