"""Multi-device Parallel SBM — paper Alg. 6/7 mapped onto a JAX mesh.

The paper sketches the distributed-memory version in §4: a distributed
sort, then the prefix computation "based on the Scatter/Gather pattern".
Here that becomes, under ``shard_map`` over a 1-D device axis:

  step ⓪  **distributed sample sort**: endpoints are bucketed by
          value-range splitters (quantiles of a *strided* sample over
          the whole stream — ``sample_splitters``) and exchanged with
          one ``all_to_all`` (the Scatter), then each device sorts its
          value-range segment locally — the bucket sort the paper cites
          (Solomonik & Kalé [57]).  XLA collectives need static shapes,
          so every (src, dst) lane carries ``cap`` slots plus a
          validity mask; overflow is detected and surfaced.
  step ①  local masked scans of active-count deltas (the counting image
          of Sadd/Sdel/Uadd/Udel, Alg. 7 lines 1-17);
  step ②  the "master" exclusive combine (Alg. 7 lines 18-21) becomes an
          ``all_gather`` of two per-device scalars + a masked sum — the
          collective prefix the paper predicts stays competitive "on
          future generations of processors with a higher number of
          cores";
  step ③  seeded local sweeps; per-device partial K returned sharded as
          int32 *block* sums (each block bounded away from the int32
          wrap), summed exactly on host in int64.

The same decomposition lowers at any mesh size — the multi-pod dry-run
compiles it across 512 devices.

Beyond counting, this module shards the engine's other two execution
paths (reached via ``MatchSpec(backend="distributed")``):

* **Pair enumeration** (``_dist_pairs_pass1`` + ``_dist_pairs_emit``)
  distributes the exact two-pass count-then-emit with *per-device
  slot-bound emission*.  Pass 1 reuses the sample sort of step ⓪ with
  an index payload, so each side's lo-sorted stream and its sort
  permutation come out of the same ``all_to_all`` exchange — no
  replicated O((n+m) lg (n+m)) ``argsort``.  The n+m *emitters*
  (class A: one per subscription; class B: one per update — see
  ``sbm._twopass_phase1``) are split into per-device contiguous
  chunks; each device computes its emitters' exact counts with
  searchsorted against the lo-sorted streams.  Pass 2 then emits each
  device's pairs into a **local** ``(cap_dev, 2)`` buffer sized by the
  max per-device total — O(K/P + P) work per device, no full-capacity
  scan and no O(cap) ``psum``; the buffers stay disjoint and sharded
  (out_specs ``P(AXIS)``) and the host assembles the dense view once,
  lazily (``core.pairs.ShardedPairs``).  d > 1 filters full
  d-dimensional overlap at emit time and compacts the holes *locally*
  inside each device's buffer.

* **Batched dynamic-service queries** (``_dist_query_counts`` /
  ``_dist_query``) shard the query batch over the mesh while the
  interval tree and opposite-kind coordinates stay replicated — the
  queries are embarrassingly parallel (paper Alg. 5 line 10), so a
  device simply runs the vmapped verified tree walk on its row chunk.
  The padding sentinels are ±inf, so integer-dtype query coordinates
  are rejected up front with a ``TypeError``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import itm
from .regions import Regions

# ``jax.shard_map`` is the new-JAX spelling; older versions ship it under
# jax.experimental with the same signature.
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - exercised only on old JAX
    from jax.experimental.shard_map import shard_map as _shard_map

Array = jax.Array
AXIS = "shards"

_INT32_MAX = 2**31 - 1


def resolve_mesh(mesh: Mesh | None) -> Mesh:
    """The spec's mesh, or a 1-D mesh over all local devices."""
    if mesh is None:
        return Mesh(np.array(jax.devices()), (AXIS,))
    return mesh


def sample_splitters(v, tot: int, nshards: int,
                     max_sample: int = 65536) -> Array:
    """Bucket splitters from an evenly strided sample of the whole stream.

    The splitter quantiles decide how evenly the sample sort fills its
    static per-(src, dst) lanes, so the sample must span the *entire*
    host-ordered stream.  A plain prefix (``v[:max_sample]``) is not a
    sample: ``_endpoints_flat`` concatenates all subscription lows
    first, so on sorted or clustered inputs a prefix sees only the
    lowest values, every splitter collapses into that range, and one
    bucket receives nearly the whole stream — a guaranteed
    ``OverflowError`` at any ``overprovision``.  Striding by
    ``tot // max_sample`` keeps the sample bounded while giving every
    value range representation.

    Returns a float32 ``(nshards - 1,)`` array (``(0,)`` for a 1-shard
    mesh).  Infinite entries (shard padding) are excluded.
    """
    if nshards <= 1:
        return jnp.zeros((0,), jnp.float32)
    qs = np.zeros((nshards - 1,), np.float32)
    if tot > 0:
        stride = max(tot // max_sample, 1)
        sample = np.asarray(v[:tot:stride], dtype=np.float64)
        sample = sample[np.isfinite(sample)]
        if sample.size:
            qs = np.quantile(
                sample, np.linspace(0, 1, nshards + 1)[1:-1]
            ).astype(np.float32)
    return jnp.asarray(qs)


def bucket_cap(tot: int, nshards: int, overprovision: float) -> int:
    """Static per-(src, dst) lane capacity for the sample-sort exchange.

    With perfect splitters each destination receives ``tot / nshards``
    values spread over ``nshards`` source lanes; ``overprovision``
    absorbs splitter skew, and the +16 floor keeps tiny streams away
    from zero-capacity lanes.
    """
    per_dev = -(-max(tot, 1) // nshards)
    return int(per_dev * overprovision / nshards) + 16


def _interleave(x, nshards: int):
    """Deal a (padded) stream round-robin across the shard dimension.

    ``shard_map`` gives device p the p-th *contiguous* chunk, so a
    value-clustered host order (sorted coordinates, the
    ``_endpoints_flat`` segment layout) concentrates one device's
    entire chunk into a single splitter bucket and overflows its
    static (src, dst) lane no matter how good the splitters are.
    After the deal, chunk p is the strided slice ``x[p::nshards]`` —
    a sample of the whole stream, so every device's sends spread over
    the buckets like the global distribution does.  Order is free to
    change: everything downstream sorts by value (with identity
    payloads where order must be recovered).
    """
    return x.reshape(-1, nshards).T.reshape(-1)


def _count_block(tot: int) -> int:
    """Largest block length whose int32 partial sum cannot wrap.

    Each element of the step-③ contribution stream is bounded by the
    total endpoint count ``tot`` (an active-set size), so a block of
    ``_INT32_MAX // tot`` elements sums to < 2³¹.  The sharded partials
    stay int32 on device (x64 is not enabled; ``jnp.int64`` would
    silently demote) and the host reduces the blocks in NumPy int64 —
    the same split as ``itm.py``'s count reduction.
    """
    return max(1, _INT32_MAX // max(tot, 1))


def _bucket_exchange(splitters, v, payloads, *, cap: int, nshards: int):
    """Step ⓪: bucket by splitters, one ``all_to_all``, per-payload.

    ``payloads`` is a list of ``(array, fill)`` carried through the
    exchange alongside ``v``.  Returns ``(received, overflow)`` where
    ``received`` has one ``(nshards * cap,)`` array per input (``v``
    first) in lane order, and ``overflow`` flags any value that did not
    fit its static lane.  Validity must be carried explicitly as a
    payload (fill 0): dropped and padded slots are indistinguishable
    from real data otherwise.
    """
    bucket = jnp.searchsorted(splitters, v, side="right").astype(jnp.int32)
    valid = payloads[-1][0]            # by convention the last payload
    bucket = jnp.where(valid > 0, bucket, nshards - 1)
    order = jnp.argsort(bucket, stable=True)
    b_sorted = bucket[order]
    starts = jnp.searchsorted(b_sorted, jnp.arange(nshards, dtype=jnp.int32),
                              side="left")
    rank = jnp.arange(b_sorted.shape[0], dtype=jnp.int32) - starts[b_sorted]
    overflow = jnp.any((rank >= cap) & (valid[order] > 0)).astype(jnp.int32)
    ok = rank < cap
    dst_b = jnp.where(ok, b_sorted, nshards)       # OOB => dropped
    dst_r = jnp.where(ok, rank, cap)

    def send(x, fill):
        buf = jnp.full((nshards, cap), fill, x.dtype)
        return buf.at[dst_b, dst_r].set(x[order], mode="drop")

    def xchg(x):
        return jax.lax.all_to_all(x, AXIS, split_axis=0,
                                  concat_axis=0).reshape(-1)

    received = [xchg(send(v, jnp.inf))]
    received.extend(xchg(send(x, fill)) for x, fill in payloads)
    return received, overflow


def _endpoints_flat(S: Regions, U: Regions):
    """Unsorted endpoint stream (v, is_lo, is_upd) — host order."""
    n, m = S.n, U.n
    v = jnp.concatenate([S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0]])
    is_lo = jnp.concatenate([jnp.ones(n, jnp.int32), jnp.zeros(n, jnp.int32),
                             jnp.ones(m, jnp.int32), jnp.zeros(m, jnp.int32)])
    is_upd = jnp.concatenate([jnp.zeros(2 * n, jnp.int32),
                              jnp.ones(2 * m, jnp.int32)])
    return v, is_lo, is_upd


def _shard_body(v, is_lo, is_upd, valid, splitters, *, cap: int,
                nshards: int, blk: int):
    """Per-device body under shard_map; all array args are local shards."""
    me = jax.lax.axis_index(AXIS)

    # -- step ⓪: sample-sort Scatter + local lex-sort of the segment ------
    (rv, rlo, rupd, rval), overflow = _bucket_exchange(
        splitters, v, [(is_lo, 0), (is_upd, 0), (valid, 0)],
        cap=cap, nshards=nshards)
    loc = jnp.lexsort((rlo, rv))        # v asc, hi-before-lo at ties
    flag_lo = rlo[loc]
    flag_upd = rupd[loc]
    val = rval[loc]
    lo_m = flag_lo * val                # masked endpoint indicators
    hi_m = (1 - flag_lo) * val
    sub_f = 1 - flag_upd

    # -- step ①: local delta scans ----------------------------------------
    d_upd = flag_upd * (lo_m - hi_m)
    d_sub = sub_f * (lo_m - hi_m)
    upd_local = jnp.cumsum(d_upd)
    sub_local = jnp.cumsum(d_sub)

    # -- step ②: exclusive combine across devices -------------------------
    totals = jnp.stack([upd_local[-1], sub_local[-1]])
    all_tot = jax.lax.all_gather(totals, AXIS)          # (P, 2)
    mask = (jnp.arange(nshards) < me)[:, None]
    carry = jnp.sum(all_tot * mask, axis=0)
    upd_active = upd_local + carry[0]
    sub_active = sub_local + carry[1]

    # -- step ③: seeded local sweep ----------------------------------------
    # Each contribution is an active-set size (< the total endpoint
    # count), so ``blk``-sized block sums are int32-exact; the host
    # finishes the reduction in int64.  A single whole-shard int32 sum
    # wraps silently once the per-device K crosses 2³¹.
    contrib = hi_m * (sub_f * upd_active + flag_upd * sub_active)
    pad = (-contrib.shape[0]) % blk
    contrib = jnp.pad(contrib, (0, pad))
    parts = jnp.sum(contrib.reshape(-1, blk), axis=1, dtype=jnp.int32)
    return parts, overflow[None]


@partial(jax.jit, static_argnames=("nshards", "cap", "blk", "mesh"))
def _dist_count(v, is_lo, is_upd, valid, splitters, *, nshards: int,
                cap: int, blk: int, mesh: Mesh):
    f = _shard_map(
        partial(_shard_body, cap=cap, nshards=nshards, blk=blk),
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P()),
        out_specs=(P(AXIS), P(AXIS)),
    )
    return f(v, is_lo, is_upd, valid, splitters)


def _distributed_count(S: Regions, U: Regions, mesh: Mesh | None = None,
                       overprovision: float = 2.5) -> int:
    """Total K via multi-device parallel SBM (1-D regions).

    ``mesh``: 1-D mesh over axis "shards"; defaults to all local devices.
    Raises ``OverflowError`` if a bucket exceeds its static capacity
    (raise ``overprovision`` — cf. sample-sort splitter quality).
    """
    assert S.d == 1
    mesh = resolve_mesh(mesh)
    nshards = int(np.prod(mesh.devices.shape))
    v, is_lo, is_upd = _endpoints_flat(S, U)
    tot = v.shape[0]
    splitters = sample_splitters(v, tot, nshards)
    pad = (-tot) % nshards
    v = _interleave(jnp.pad(v, (0, pad), constant_values=jnp.inf), nshards)
    is_lo = _interleave(jnp.pad(is_lo, (0, pad)), nshards)
    is_upd = _interleave(jnp.pad(is_upd, (0, pad)), nshards)
    valid = _interleave(jnp.pad(jnp.ones(tot, jnp.int32), (0, pad)),
                        nshards)

    cap = bucket_cap(tot, nshards, overprovision)
    parts, overflow = _dist_count(v, is_lo, is_upd, valid, splitters,
                                  nshards=nshards, cap=cap,
                                  blk=_count_block(tot), mesh=mesh)
    if int(np.max(np.asarray(overflow))) > 0:
        raise OverflowError(
            "distributed SBM bucket overflow; raise overprovision")
    return int(np.sum(np.asarray(parts), dtype=np.int64))


# ---------------------------------------------------------------------------
# Distributed two-pass pair enumeration — sharded count, per-device
# slot-bound emit
# ---------------------------------------------------------------------------

def _sort_side_body(v, ids, valid, splitters, *, cap: int, nshards: int):
    """Step ⓪ with an index payload: one side's lo endpoints, sorted.

    Each device buckets its local chunk, exchanges via ``all_to_all``,
    and sorts its received value-range segment with the original row
    index riding along — valid entries first (invalid slots key to
    +inf).  Concatenated over the mesh the valid entries are globally
    value-sorted, so compacting them (host of the jit, still traced)
    reproduces exactly what a replicated ``argsort`` used to build,
    from the same exchange the counting path already does.
    """
    (rv, rid, rval), overflow = _bucket_exchange(
        splitters, v, [(ids, 0), (valid, 0)], cap=cap, nshards=nshards)
    key = jnp.where(rval > 0, rv, jnp.inf)
    loc = jnp.argsort(key)
    return key[loc], rid[loc], rval[loc], overflow[None]


def _dist_lo_sort(v, *, splitters, cap: int, nshards: int, mesh: Mesh):
    """Distributed sample sort of one side's lo endpoints + permutation.

    Returns ``(sorted_v (nv,), perm (nv,) int32, overflow scalar)``;
    ``sorted_v[i] = v[perm[i]]`` ascending.  The local segments come
    back sharded; the replicated compaction below is O(P² · cap) adds —
    independent of K and tiny next to the emit.  The segments are
    explicitly re-replicated (one all_gather) *before* the compaction
    scatter: left sharded, GSPMD partitions the scatter itself, which
    on CPU meshes lowers to a serialized cross-device loop ~200×
    slower than the replicated scatter it replaces.
    """
    nv = v.shape[0]
    ids = jnp.arange(nv, dtype=jnp.int32)
    valid = jnp.ones(nv, jnp.int32)
    pad = (-nv) % nshards
    if pad:
        v = jnp.pad(v, (0, pad), constant_values=jnp.inf)
        ids = jnp.pad(ids, (0, pad), constant_values=0)
        valid = jnp.pad(valid, (0, pad), constant_values=0)
    v = _interleave(v, nshards)         # sorted input must not cluster
    ids = _interleave(ids, nshards)
    valid = _interleave(valid, nshards)
    f = _shard_map(
        partial(_sort_side_body, cap=cap, nshards=nshards),
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P()),
        out_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
    )
    gv, gid, gval, ovf = f(v, ids, valid, splitters)
    rep = jax.sharding.NamedSharding(mesh, P())
    gv = jax.lax.with_sharding_constraint(gv, rep)
    gid = jax.lax.with_sharding_constraint(gid, rep)
    gval = jax.lax.with_sharding_constraint(gval, rep)
    ok = gval > 0
    dst = jnp.cumsum(ok.astype(jnp.int32)) - 1
    tgt = jnp.where(ok, dst, nv)                   # OOB => dropped
    sorted_v = jnp.full((nv,), jnp.inf, v.dtype).at[tgt].set(gv, mode="drop")
    perm = jnp.zeros((nv,), jnp.int32).at[tgt].set(gid, mode="drop")
    return sorted_v, perm, jnp.sum(ovf)


def _chunk_ranges(emit_lo, emit_hi, u_lo_sorted, s_lo_sorted):
    """Pass-1 ranges for this device's emitter chunk.

    Both emitter classes are searchsorted ranges over the lo-sorted
    streams (``sbm._twopass_phase1``): class A (one emitter per
    subscription) counts updates whose lo falls in [emit_lo, emit_hi);
    class B (one per update) counts subscriptions strictly containing
    its lo.  Returns ``(gid, is_b, start, cnt)``; padding emitters
    (``gid >= n + m``) count zero.
    """
    me = jax.lax.axis_index(AXIS)
    n = s_lo_sorted.shape[0]
    m = u_lo_sorted.shape[0]
    chunk = emit_lo.shape[0]
    gid = me * chunk + jnp.arange(chunk, dtype=jnp.int32)
    alive = gid < (n + m)
    is_b = gid >= n
    aA = jnp.searchsorted(u_lo_sorted, emit_lo, side="left")
    rA = jnp.searchsorted(u_lo_sorted, emit_hi, side="left")
    bB = jnp.searchsorted(s_lo_sorted, emit_lo, side="right")
    cB = jnp.searchsorted(s_lo_sorted, emit_hi, side="left")
    start = jnp.where(is_b, bB, aA).astype(jnp.int32)
    end = jnp.where(is_b, cB, rA).astype(jnp.int32)
    cnt = jnp.where(alive, jnp.maximum(end - start, 0), 0)
    return gid, is_b, start, cnt


def _pairs_count_body(emit_lo, emit_hi, u_lo_sorted, s_lo_sorted):
    """Per-device pass 1: exact dim-0 counts for the local emitter chunk."""
    return _chunk_ranges(emit_lo, emit_hi, u_lo_sorted, s_lo_sorted)[3]


def _pairs_emit_body(emit_lo, emit_hi, u_lo_sorted, s_lo_sorted, perm_s,
                     perm_u, S_lo, S_hi, U_lo, U_hi, *, cap_dev: int,
                     nshards: int):
    """Per-device slot-bound emit: the local chunk → a local buffer.

    Every device recomputes its chunk's pass-1 ranges, scans them into
    *local* slot offsets (saturating at ``cap_dev`` so int32 never
    wraps and truncation never corrupts the emitted prefix — the same
    convention as the local ``_twopass_phase1``), and decodes its own
    ``cap_dev`` slots: O(K/P + P) work per device, against the old
    global-buffer emit's O(P·K) full-capacity scan + O(cap) ``psum``.
    The d > 1 overlap filter runs here too, and the surviving rows are
    compacted *locally* (the engine's ``select_rows`` idiom), so the
    returned ``(cap_dev, 2)`` buffer is a −1-padded prefix — no global
    recompaction pass.  ``ver`` is this device's verified-pair total.
    """
    n, m = S_lo.shape[0], U_lo.shape[0]
    chunk = emit_lo.shape[0]
    gid, is_b, start, cnt = _chunk_ranges(emit_lo, emit_hi, u_lo_sorted,
                                          s_lo_sorted)

    lim = jnp.int32(cap_dev)
    sat = lambda a, b: jnp.minimum(a + b, lim)            # noqa: E731
    incl = jax.lax.associative_scan(sat, jnp.minimum(cnt, lim))
    total = incl[-1]
    loffs = jnp.concatenate([jnp.zeros((1,), jnp.int32), incl])

    j = jnp.arange(cap_dev, dtype=jnp.int32)
    e = jnp.clip(jnp.searchsorted(loffs, j, side="right").astype(jnp.int32)
                 - 1, 0, chunk - 1)
    rank = j - loffs[e]
    kidx = start[e] + rank
    eb = is_b[e]
    s_idx = jnp.where(eb, perm_s[jnp.clip(kidx, 0, n - 1)],
                      jnp.clip(gid[e], 0, n - 1))
    u_idx = jnp.where(eb, jnp.clip(gid[e] - n, 0, m - 1),
                      perm_u[jnp.clip(kidx, 0, m - 1)])
    in_stream = j < total
    # emit-time d-dim filter on dims 1..d-1 (vacuously true at d == 1)
    ok_d = jnp.all(jnp.logical_and(S_lo[s_idx, 1:] < U_hi[u_idx, 1:],
                                   U_lo[u_idx, 1:] < S_hi[s_idx, 1:]),
                   axis=-1)
    keep = in_stream & ok_d
    rows = jnp.stack([s_idx, u_idx], axis=1)
    sel = jnp.nonzero(keep, size=cap_dev, fill_value=-1)[0]
    buf = jnp.where(sel[:, None] >= 0, rows[jnp.maximum(sel, 0)], -1)
    ver = jnp.sum(keep, dtype=jnp.int32)
    return buf, ver[None]


def _pad_emitters(S_lo, S_hi, U_lo, U_hi, nshards: int):
    """The n+m dim-0 emitter intervals, padded to a multiple of P."""
    emit_lo = jnp.concatenate([S_lo[:, 0], U_lo[:, 0]])
    emit_hi = jnp.concatenate([S_hi[:, 0], U_hi[:, 0]])
    pad = (-emit_lo.shape[0]) % nshards
    if pad:
        emit_lo = jnp.pad(emit_lo, (0, pad))
        emit_hi = jnp.pad(emit_hi, (0, pad))
    return emit_lo, emit_hi


def _dist_pairs_pass1(S_lo, S_hi, U_lo, U_hi, split_s, split_u, *,
                      cap_s: int, cap_u: int, nshards: int, mesh: Mesh):
    """Distributed sorts + sharded exact counts (jit via the caller).

    Returns ``(counts, s_sorted, perm_s, u_sorted, perm_u, overflow)``:
    ``counts`` the per-emitter exact dim-0 counts (n+m padded, int32,
    sharded — the host sums them in int64 for the exact K *and* reduces
    them per device to size the emit buffers), the two lo-sorted
    streams with their sort permutations (built by the distributed
    sample sort — pair identities survive the ``all_to_all``), and the
    summed sort-overflow flag (the caller raises ``OverflowError``).
    """
    s_sorted, perm_s, ovf_s = _dist_lo_sort(
        S_lo[:, 0], splitters=split_s, cap=cap_s, nshards=nshards,
        mesh=mesh)
    u_sorted, perm_u, ovf_u = _dist_lo_sort(
        U_lo[:, 0], splitters=split_u, cap=cap_u, nshards=nshards,
        mesh=mesh)
    emit_lo, emit_hi = _pad_emitters(S_lo, S_hi, U_lo, U_hi, nshards)
    f = _shard_map(
        _pairs_count_body,
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(), P()),
        out_specs=P(AXIS),
    )
    counts = f(emit_lo, emit_hi, u_sorted, s_sorted)
    return counts, s_sorted, perm_s, u_sorted, perm_u, ovf_s + ovf_u


def _dist_pairs_emit(S_lo, S_hi, U_lo, U_hi, u_sorted, s_sorted, perm_s,
                     perm_u, *, cap_dev: int, nshards: int, mesh: Mesh):
    """Per-device slot-bound emit (jit via the caller).

    Returns ``(bufs, ver)``: ``bufs`` the gathered ``(P · cap_dev, 2)``
    stack of per-device −1-padded local buffers (still sharded —
    device p's pairs occupy rows ``[p·cap_dev, p·cap_dev + ver[p])``),
    ``ver`` the (P,) per-device verified-pair totals.  The engine wraps
    both in a ``core.pairs.ShardedPairs`` that assembles the dense
    ``(cap, 2)`` view lazily on host.
    """
    emit_lo, emit_hi = _pad_emitters(S_lo, S_hi, U_lo, U_hi, nshards)
    f = _shard_map(
        partial(_pairs_emit_body, cap_dev=cap_dev, nshards=nshards),
        mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(), P(), P(), P(),
                  P(), P(), P(), P()),
        out_specs=(P(AXIS), P(AXIS)),
    )
    return f(emit_lo, emit_hi, u_sorted, s_sorted, perm_s, perm_u,
             S_lo, S_hi, U_lo, U_hi)


# ---------------------------------------------------------------------------
# Distributed batched dynamic-service queries — tree replicated, queries
# sharded (embarrassingly parallel, paper Alg. 5 line 10)
# ---------------------------------------------------------------------------

def _shard_map_norep(f, *, mesh, in_specs, out_specs):
    """shard_map without the replication checker: the vmapped tree walks
    are ``while_loop``s, for which check_rep has no rule (outputs here
    are all row-sharded, so nothing is lost).  Newer JAX drops the
    kwarg — fall back to the plain call there."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover - future-JAX spelling
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)


def _require_float_queries(fn: str, **named):
    """Sharding pads query batches with ±inf pruning sentinels, which do
    not exist in integer dtypes (``jnp.pad`` would wrap them to INT_MIN
    and the padded rows would *match*).  Reject non-floating query
    coordinates up front with an actionable error; runs at trace time,
    and a dtype change forces a retrace, so no call can skip it."""
    for name, a in named.items():
        if not jnp.issubdtype(jnp.asarray(a).dtype, jnp.floating):
            raise TypeError(
                f"{fn}: query coordinates must be a floating dtype "
                f"(the sharded batch is padded with ±inf sentinels), "
                f"got {name} with dtype {jnp.asarray(a).dtype} — cast "
                "the query boxes to float32/float64 before plan.query()")


def _query_counts_body(tree, q_lo0, q_hi0):
    return itm.itm_query_counts(tree, q_lo0, q_hi0)


def _dist_query_counts(tree, q_lo0, q_hi0, *, nshards: int, mesh: Mesh):
    """Per-query dim-0 candidate counts, query rows sharded over the mesh.

    The host reduces the gathered counts to the global max — that single
    reduction is what sizes the shared query capacity under ``grow``.
    """
    _require_float_queries("_dist_query_counts", q_lo0=q_lo0, q_hi0=q_hi0)
    b = q_lo0.shape[0]
    pad = (-b) % nshards
    if pad:
        # impossible boxes: pruned at the root, zero candidates
        q_lo0 = jnp.pad(q_lo0, (0, pad), constant_values=jnp.inf)
        q_hi0 = jnp.pad(q_hi0, (0, pad), constant_values=-jnp.inf)
    f = _shard_map_norep(_query_counts_body, mesh=mesh,
                         in_specs=(P(), P(AXIS), P(AXIS)),
                         out_specs=P(AXIS))
    return f(tree, q_lo0, q_hi0)[:b]


def _query_body(tree, o_lo, o_hi, q_lo, q_hi, *, cap: int):
    return itm.itm_query_pairs_dd(tree, o_lo, o_hi, q_lo, q_hi, cap=cap)


def _dist_query(tree, o_lo, o_hi, q_lo, q_hi, *, cap: int, nshards: int,
                mesh: Mesh):
    """Sharded verified d-dim batched query (engine ``plan.query`` path)."""
    _require_float_queries("_dist_query", q_lo=q_lo, q_hi=q_hi)
    b = q_lo.shape[0]
    pad = (-b) % nshards
    if pad:
        q_lo = jnp.pad(q_lo, ((0, pad), (0, 0)), constant_values=jnp.inf)
        q_hi = jnp.pad(q_hi, ((0, pad), (0, 0)), constant_values=-jnp.inf)
    f = _shard_map_norep(partial(_query_body, cap=cap), mesh=mesh,
                         in_specs=(P(), P(), P(), P(AXIS), P(AXIS)),
                         out_specs=(P(AXIS), P(AXIS)))
    ids, cnt = f(tree, o_lo, o_hi, q_lo, q_hi)
    return ids[:b], cnt[:b]
