"""One result contract for pair enumeration — ``PairsResult``.

``MatchPlan.pairs()`` historically returned either a dense ``(cap, 2)``
int32 −1-padded device array or (on the CSR emit route) a duck-typed
lazy view.  Every consumer had to know which one it got.  This module
defines the single contract both shapes implement:

* ``count`` — the exact total K (python int), even when the buffer
  capacity truncates;
* ``cap`` / ``shape`` / ``dtype`` / ``__len__`` — the static buffer
  geometry (``(cap, 2)`` int32);
* ``decode(start, stop)`` — the dense slice of slots ``[start, stop)``,
  bit-identical across implementations: real pairs in slot order below
  ``min(count, cap)``, −1 pads above it;
* ``windows(chunk)`` — ``(start, np.ndarray)`` chunks in slot order,
  the streaming consumption path that never materializes O(cap) at
  once;
* ``to_dense()`` — the full dense device buffer;
* ``__array__`` — the full dense host buffer (NumPy protocol), so
  ``np.asarray(result)`` works everywhere a raw buffer used to;
* ``nbytes`` — device bytes actually held (the compressed form for a
  lazy view, the buffer itself for a dense one).

``DensePairs`` is the thin wrapper over an in-memory dense buffer;
``kernels.ops.CSRPairs`` subclasses ``PairsResult`` for the lazy CSR
decode view; ``ShardedPairs`` wraps the distributed backend's stack of
per-device emit buffers and assembles the dense view lazily on host.
``dd_match.pairs_to_set`` and ``MatchPlan.validate_pairs`` consume any
``PairsResult`` window by window.
"""
from __future__ import annotations

import numpy as np


class PairsResult:
    """Abstract pair-enumeration result (see module docstring).

    Subclasses must set ``cap`` and ``count`` (ints) and implement
    ``decode`` and ``nbytes``; everything else derives from those.
    """

    cap: int
    count: int

    @property
    def shape(self) -> tuple[int, int]:
        return (self.cap, 2)

    @property
    def dtype(self):
        return np.int32

    def __len__(self) -> int:
        return self.cap

    @property
    def nbytes(self) -> int:
        """Device bytes actually held by this result."""
        raise NotImplementedError

    @property
    def dense_nbytes(self) -> int:
        """Bytes a dense (cap, 2) int32 buffer would occupy."""
        return self.cap * 2 * 4

    def _check_window(self, start: int, stop: int | None) -> int:
        stop = self.cap if stop is None else stop
        if not 0 <= start <= stop <= self.cap:
            raise ValueError(
                f"decode window [{start}, {stop}) outside [0, {self.cap}]")
        return stop

    def decode(self, start: int = 0, stop: int | None = None):
        """Dense int32 (stop−start, 2) device slice of slots
        [start, stop) — real pairs below ``min(count, cap)``, −1 pads
        above, identically across every implementation."""
        raise NotImplementedError

    def windows(self, chunk: int = 1 << 16):
        """Yield ``(start, np.ndarray)`` dense chunks in slot order."""
        for w0 in range(0, self.cap, chunk):
            yield w0, np.asarray(self.decode(w0, min(w0 + chunk,
                                                     self.cap)))

    def to_dense(self):
        """Full dense (cap, 2) device buffer."""
        return self.decode(0, self.cap)

    def __array__(self, dtype=None, copy=None):
        out = np.full((self.cap, 2), -1, np.int32)
        for w0, w in self.windows():
            out[w0:w0 + w.shape[0]] = w
        return out if dtype is None else out.astype(dtype)


class DensePairs(PairsResult):
    """``PairsResult`` over an in-memory dense ``(cap, 2)`` buffer.

    ``data`` is the device (or host) int32 −1-padded buffer the
    resident/streaming/xla emit routes produce; ``count`` is the exact
    K.  ``decode`` is a plain slice (no kernel round-trip) and
    ``__getitem__`` delegates to the underlying buffer, so existing
    array-style consumers (``pairs[k:]``, ``np.asarray(pairs)``) keep
    working unchanged.
    """

    def __init__(self, data, count: int):
        self.data = data
        self.cap = int(data.shape[0])
        self.count = int(count)

    @property
    def nbytes(self) -> int:
        return self.cap * 2 * 4

    def decode(self, start: int = 0, stop: int | None = None):
        stop = self._check_window(start, stop)
        return self.data[start:stop]

    def __getitem__(self, idx):
        return self.data[idx]

    def __array__(self, dtype=None, copy=None):
        out = np.asarray(self.data)
        return out if dtype is None else out.astype(dtype)

    def __repr__(self) -> str:
        return (f"DensePairs(cap={self.cap}, count={self.count}, "
                f"nbytes={self.nbytes})")


class ShardedPairs(PairsResult):
    """``PairsResult`` over the distributed backend's per-device buffers.

    ``data`` is the gathered ``(nshards * cap_dev, 2)`` int32 stack of
    per-device slot-bound emit buffers — device p's pairs are the
    −1-padded prefix of rows ``[p * cap_dev, (p+1) * cap_dev)``, and
    ``dev_counts[p]`` is that prefix's length.  Device chunks are
    disjoint and in global emitter order, so concatenating the valid
    prefixes in device order *is* the dense emission-order buffer; the
    concatenation (one device→host transfer + O(cap) copy) runs lazily
    on first ``decode``/``__array__`` and is cached.  ``nbytes`` is the
    sharded footprint actually held — ``cap_dev`` rows per device, not
    the dense ``cap``.
    """

    def __init__(self, data, dev_counts, cap: int, count: int):
        self.data = data
        self.dev_counts = np.asarray(dev_counts, dtype=np.int64)
        self.nshards = int(self.dev_counts.shape[0])
        self.cap_dev = int(data.shape[0]) // self.nshards
        self.cap = int(cap)
        self.count = int(count)
        self._dense_host: np.ndarray | None = None

    @property
    def nbytes(self) -> int:
        return int(self.data.shape[0]) * 2 * 4

    def _dense(self) -> np.ndarray:
        if self._dense_host is None:
            raw = np.asarray(self.data).reshape(self.nshards,
                                                self.cap_dev, 2)
            out = np.full((self.cap, 2), -1, np.int32)
            pos = 0
            for p in range(self.nshards):
                take = min(int(self.dev_counts[p]), self.cap - pos)
                if take > 0:
                    out[pos:pos + take] = raw[p, :take]
                pos += take
                if pos >= self.cap:
                    break
            self._dense_host = out
        return self._dense_host

    def decode(self, start: int = 0, stop: int | None = None):
        stop = self._check_window(start, stop)
        return self._dense()[start:stop]

    def __array__(self, dtype=None, copy=None):
        out = self._dense()
        return out if dtype is None else out.astype(dtype)

    def __repr__(self) -> str:
        return (f"ShardedPairs(cap={self.cap}, count={self.count}, "
                f"nshards={self.nshards}, cap_dev={self.cap_dev}, "
                f"nbytes={self.nbytes})")
