"""Grid-Based Matching (GBM) — paper Algorithm 3, race-free TPU form.

Two OpenMP-era problems are removed structurally (DESIGN.md §2):

* the *scatter race* on per-cell lists (paper line 8, needing a critical
  section) becomes a two-pass bucketing: expand (region → overlapped cell)
  incidences, stable-sort by cell, then compute per-cell offsets with
  ``searchsorted`` — no mutation, no lock;
* the *duplicate-report* problem (paper's ``res`` hash-set, line 15)
  becomes the stateless **first-overlapped-cell test**: a pair (s, u) is
  counted only in the cell containing ``max(s.lo, u.lo)``, which is always
  a shared cell of an overlapping pair — each intersection is counted
  exactly once with a branch-free compare instead of a set lookup.

Per-cell matching is the tiled brute-force compare (the paper notes GBM
degenerates to BFM within a cell).  Capacities (max cells spanned by one
region, max regions per cell) are measured host-side and passed as static
shapes — the XLA analogue of the paper's dynamically-sized lists.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .regions import Regions

Array = jax.Array


def _cell_of(x, lb, width, ncells):
    c = jnp.floor((x - lb) / width).astype(jnp.int32)
    return jnp.clip(c, 0, ncells - 1)


@partial(jax.jit, static_argnames=("ncells",))
def _cell_spans(lo, hi, lb, width, ncells: int):
    """First/last grid cell overlapped by each 1-D region (inclusive)."""
    c0 = _cell_of(lo, lb, width, ncells)
    # floor((hi-lb)/width) >= cell(x) for every x < hi, and the boundary
    # cell (hi exactly on an edge) contains no point of [lo, hi):
    ch = jnp.floor((hi - lb) / width).astype(jnp.int32)
    on_edge = (lb + ch.astype(lo.dtype) * width) >= hi
    c1 = jnp.clip(ch - on_edge.astype(jnp.int32), c0, ncells - 1)
    return c0, c1


@partial(jax.jit, static_argnames=("ncells", "max_span", "cap"))
def _bucketize(lo, hi, lb, width, ncells: int, max_span: int, cap: int):
    """(ncells, cap) member-index table (−1 padded) via sort-by-cell."""
    n = lo.shape[0]
    c0, c1 = _cell_spans(lo, hi, lb, width, ncells)
    k = jnp.arange(max_span)[None, :]
    cells = c0[:, None] + k                            # (n, max_span)
    valid = cells <= c1[:, None]
    cells = jnp.where(valid, cells, ncells)            # overflow bucket
    ridx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None],
                            cells.shape)
    flat_c = cells.ravel()
    flat_r = ridx.ravel()
    order = jnp.argsort(flat_c, stable=True)
    sc, sr = flat_c[order], flat_r[order]
    starts = jnp.searchsorted(sc, jnp.arange(ncells, dtype=jnp.int32),
                              side="left")
    # rank of entry within its cell
    rank = jnp.arange(sc.shape[0], dtype=jnp.int32) - starts[jnp.minimum(
        sc, ncells - 1)]
    ok = (sc < ncells) & (rank >= 0) & (rank < cap)
    cell_idx = jnp.where(ok, sc, ncells)   # out-of-bounds => dropped
    rank_idx = jnp.where(ok, rank, cap)
    table = jnp.full((ncells, cap), -1, jnp.int32)
    table = table.at[cell_idx, rank_idx].set(sr, mode="drop")
    return table


@partial(jax.jit, static_argnames=("ncells", "cap_s", "cap_u", "span_s",
                                   "span_u", "chunk"))
def _gbm_cell_counts(S: Regions, U: Regions, lb, width, ncells: int,
                     cap_s: int, cap_u: int, span_s: int, span_u: int,
                     chunk: int):
    s_lo, s_hi = S.lo[:, 0], S.hi[:, 0]
    u_lo, u_hi = U.lo[:, 0], U.hi[:, 0]
    ts = _bucketize(s_lo, s_hi, lb, width, ncells, span_s, cap_s)
    tu = _bucketize(u_lo, u_hi, lb, width, ncells, span_u, cap_u)

    nchunks = ncells // chunk
    ts = ts.reshape(nchunks, chunk, cap_s)
    tu = tu.reshape(nchunks, chunk, cap_u)
    cell_ids = jnp.arange(ncells, dtype=jnp.int32).reshape(nchunks, chunk)

    def per_chunk(carry, args):
        tsc, tuc, cid = args                     # (chunk,cap_s) etc.
        sl = s_lo[jnp.maximum(tsc, 0)]
        sh = s_hi[jnp.maximum(tsc, 0)]
        ul = u_lo[jnp.maximum(tuc, 0)]
        uh = u_hi[jnp.maximum(tuc, 0)]
        vs = tsc >= 0
        vu = tuc >= 0
        ov = (sl[:, :, None] < uh[:, None, :]) & \
             (ul[:, None, :] < sh[:, :, None])
        # first-overlapped-cell dedup: count only where the cell owns
        # max(s.lo, u.lo)
        own = _cell_of(jnp.maximum(sl[:, :, None], ul[:, None, :]),
                       lb, width, ncells) == cid[:, None, None]
        ok = ov & own & vs[:, :, None] & vu[:, None, :]
        return carry, jnp.sum(ok, dtype=jnp.int32)

    _, per_chunk_counts = jax.lax.scan(per_chunk, 0, (ts, tu, cell_ids))
    return per_chunk_counts


def _capacities(lo, hi, lb, width, ncells):
    """Host-side pre-pass: max cells per region, max regions per cell."""
    c0, c1 = _cell_spans(jnp.asarray(lo), jnp.asarray(hi),
                         jnp.float32(lb), jnp.float32(width), ncells)
    c0n, c1n = np.asarray(c0), np.asarray(c1)
    span = int((c1n - c0n).max()) + 1
    # occupancy per cell via difference array
    diff = np.bincount(c0n, minlength=ncells + 1).astype(np.int64)
    diff -= np.bincount(np.minimum(c1n + 1, ncells), minlength=ncells + 1)
    occ = np.cumsum(diff[:ncells])
    return span, max(int(occ.max()), 1)


# ---------------------------------------------------------------------------
# Hybrid grid+SBM (hsbm) geometry — host-side measurement
# ---------------------------------------------------------------------------
#
# The hybrid algorithm replaces flat SBM's pass-1 *global* lo-sorts with a
# coarse grid bucketing followed by per-cell segmented sorts: O(n lg n)
# drops to O(n lg(n/ncells)) comparisons and, more importantly on wide
# machines, every cell sorts a short padded row independently.  The grid
# here is only a pre-filter — matching within/across cell boundaries stays
# the exact SBM searchsorted-range argument, so hsbm inherits SBM's
# exactness rather than GBM's first-overlapped-cell dedup discipline.
#
# Everything static about the computation (cell count, per-cell capacity,
# boundary-suffix width) is measured on the host from the actual data,
# then rounded to coarse quanta so repeated builds over same-distribution
# data reuse the jit cache (zero steady-state retrace).

_HSBM_TARGET_OCC = 1280     # aim for ~this many regions per cell pair
_HSBM_MAX_NCELLS = 1 << 16


@jax.tree_util.register_static
class HsbmGeometry:
    """Static grid geometry for the hybrid grid+SBM pass 1.

    ``ncells``/``cap_s``/``cap_u``/``suf_s``/``suf_u`` are static shape
    parameters (python ints); ``lb``/``width`` are the grid origin and
    cell width (python floats, passed to kernels as traced f32 scalars so
    value changes never retrace).
    """

    def __init__(self, ncells: int, cap_s: int, suf_s: int, cap_u: int,
                 suf_u: int, lb: float, width: float):
        self.ncells = int(ncells)
        self.cap_s = int(cap_s)
        self.suf_s = int(suf_s)
        self.cap_u = int(cap_u)
        self.suf_u = int(suf_u)
        self.lb = float(lb)
        self.width = float(width)

    @property
    def n_emit_s(self) -> int:
        """Rows of the padded S emitter table (natives + spill suffix)."""
        return self.ncells * (self.cap_s + self.suf_s)

    @property
    def n_emit_u(self) -> int:
        return self.ncells * (self.cap_u + self.suf_u)

    def statics(self) -> dict:
        return dict(ncells=self.ncells, cap_s=self.cap_s, suf_s=self.suf_s,
                    cap_u=self.cap_u, suf_u=self.suf_u)

    def _key(self):
        return (self.ncells, self.cap_s, self.suf_s, self.cap_u,
                self.suf_u, self.lb, self.width)

    def __eq__(self, other):
        return (isinstance(other, HsbmGeometry)
                and self._key() == other._key())

    def __hash__(self):
        return hash(self._key())

    def __repr__(self):
        return (f"HsbmGeometry(ncells={self.ncells}, cap_s={self.cap_s}, "
                f"suf_s={self.suf_s}, cap_u={self.cap_u}, "
                f"suf_u={self.suf_u}, lb={self.lb}, width={self.width})")


def _pow2_ceil(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


def hsbm_geometry(s_lo, s_hi, u_lo, u_hi,
                  ncells: int | None = None) -> HsbmGeometry:
    """Measure the hybrid grid geometry on the host (pure NumPy).

    ``ncells=None`` picks pow2_ceil((n+m)/1280) cells — the measured
    sweet spot on the reference workloads — clamped so each cell is at
    least one max-region-length wide (then a region's lo-cell and the
    cell left of it are the only cells whose natives can reach it, which
    the boundary-suffix construction in ``sbm._hsbm_side_tables``
    relies on).  Per-cell native capacity is measured with the *exact*
    float32 arithmetic the device uses (bitwise-identical cell
    assignment); the spill-suffix width is measured conservatively in
    float64 so rounding can only widen the suffix, never miss a
    boundary-crossing region.
    """
    s_lo = np.asarray(s_lo, np.float32)
    s_hi = np.asarray(s_hi, np.float32)
    u_lo = np.asarray(u_lo, np.float32)
    u_hi = np.asarray(u_hi, np.float32)
    n, m = s_lo.shape[0], u_lo.shape[0]
    lb = float(min(s_lo.min(), u_lo.min()))
    top = float(max(s_hi.max(), u_hi.max()))
    max_len64 = float(max((s_hi.astype(np.float64) - s_lo).max(),
                          (u_hi.astype(np.float64) - u_lo).max()))
    if ncells is None:
        ncells = _pow2_ceil(max(1, (n + m) // _HSBM_TARGET_OCC))
    span_bound = (max(1, int((top - lb) / max_len64))
                  if max_len64 > 0 and top > lb else 1)
    nc = max(1, min(int(ncells), span_bound, _HSBM_MAX_NCELLS))
    slack = max(abs(lb), abs(top)) * 2.0 ** -20 + 1e-300

    def one_side(lo, width):
        c = np.floor((lo - np.float32(lb)) / np.float32(width))
        c = np.minimum(c.astype(np.int64), nc - 1)
        occ = np.bincount(c, minlength=nc)
        cap = max(64, -(-int(occ.max()) // 64) * 64)
        # a region native to cell c−1 can reach cell c iff
        # lo ≥ cell_c_left_edge − max_len; measure how many sit in that
        # suffix window per cell, with f64 slack so the threshold is
        # conservative under f32 rounding
        thresh = (lb + (c + 1) * width) - max_len64 - slack
        sufc = np.bincount(c[lo.astype(np.float64) >= thresh], minlength=nc)
        suf = max(8, -(-int(sufc.max()) // 8) * 8)
        return cap, suf

    while True:
        # the (1 + 1e-6) guard keeps floor((top − lb)/width) ≤ nc even
        # after the division is redone in f32 on the device
        width = (top - lb) / nc * (1 + 1e-6) if top > lb else 1.0
        cap_s, suf_s = one_side(s_lo, width)
        cap_u, suf_u = one_side(u_lo, width)
        rows = nc * (cap_s + suf_s + cap_u + suf_u)
        # padded-table blow-up guard: on skewed data per-cell max
        # occupancy times ncells can dwarf n+m; halve the grid until the
        # emitter tables stay within 4x the input (also keeps every
        # shifted emitter id comfortably inside int32)
        if nc == 1 or rows <= max(4 * (n + m), 1 << 16):
            break
        nc //= 2
    return HsbmGeometry(nc, cap_s, suf_s, cap_u, suf_u, lb, width)


def gbm_count(S: Regions, U: Regions, ncells: int = 3000,
              chunk: int | None = None) -> int:
    """Total K via grid matching.  ``ncells`` is the paper's tuning knob."""
    assert S.d == 1
    lb = float(min(jnp.min(S.lo), jnp.min(U.lo)))
    ub = float(max(jnp.max(S.hi), jnp.max(U.hi)))
    width = max((ub - lb) / ncells, 1e-30)
    span_s, cap_s = _capacities(S.lo[:, 0], S.hi[:, 0], lb, width, ncells)
    span_u, cap_u = _capacities(U.lo[:, 0], U.hi[:, 0], lb, width, ncells)
    if chunk is None:
        # keep the (chunk, cap_s, cap_u) compare block around ~2^22 elems
        chunk = max(1, min(ncells, (1 << 22) // max(cap_s * cap_u, 1)))
    while ncells % chunk:
        chunk -= 1
    counts = _gbm_cell_counts(S, U, jnp.float32(lb), jnp.float32(width),
                              ncells, cap_s, cap_u, span_s, span_u, chunk)
    return int(np.sum(np.asarray(counts), dtype=np.int64))
