"""Grid-Based Matching (GBM) — paper Algorithm 3, race-free TPU form.

Two OpenMP-era problems are removed structurally (DESIGN.md §2):

* the *scatter race* on per-cell lists (paper line 8, needing a critical
  section) becomes a two-pass bucketing: expand (region → overlapped cell)
  incidences, stable-sort by cell, then compute per-cell offsets with
  ``searchsorted`` — no mutation, no lock;
* the *duplicate-report* problem (paper's ``res`` hash-set, line 15)
  becomes the stateless **first-overlapped-cell test**: a pair (s, u) is
  counted only in the cell containing ``max(s.lo, u.lo)``, which is always
  a shared cell of an overlapping pair — each intersection is counted
  exactly once with a branch-free compare instead of a set lookup.

Per-cell matching is the tiled brute-force compare (the paper notes GBM
degenerates to BFM within a cell).  Capacities (max cells spanned by one
region, max regions per cell) are measured host-side and passed as static
shapes — the XLA analogue of the paper's dynamically-sized lists.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .regions import Regions

Array = jax.Array


def _cell_of(x, lb, width, ncells):
    c = jnp.floor((x - lb) / width).astype(jnp.int32)
    return jnp.clip(c, 0, ncells - 1)


@partial(jax.jit, static_argnames=("ncells",))
def _cell_spans(lo, hi, lb, width, ncells: int):
    """First/last grid cell overlapped by each 1-D region (inclusive)."""
    c0 = _cell_of(lo, lb, width, ncells)
    # floor((hi-lb)/width) >= cell(x) for every x < hi, and the boundary
    # cell (hi exactly on an edge) contains no point of [lo, hi):
    ch = jnp.floor((hi - lb) / width).astype(jnp.int32)
    on_edge = (lb + ch.astype(lo.dtype) * width) >= hi
    c1 = jnp.clip(ch - on_edge.astype(jnp.int32), c0, ncells - 1)
    return c0, c1


@partial(jax.jit, static_argnames=("ncells", "max_span", "cap"))
def _bucketize(lo, hi, lb, width, ncells: int, max_span: int, cap: int):
    """(ncells, cap) member-index table (−1 padded) via sort-by-cell."""
    n = lo.shape[0]
    c0, c1 = _cell_spans(lo, hi, lb, width, ncells)
    k = jnp.arange(max_span)[None, :]
    cells = c0[:, None] + k                            # (n, max_span)
    valid = cells <= c1[:, None]
    cells = jnp.where(valid, cells, ncells)            # overflow bucket
    ridx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None],
                            cells.shape)
    flat_c = cells.ravel()
    flat_r = ridx.ravel()
    order = jnp.argsort(flat_c, stable=True)
    sc, sr = flat_c[order], flat_r[order]
    starts = jnp.searchsorted(sc, jnp.arange(ncells, dtype=jnp.int32),
                              side="left")
    # rank of entry within its cell
    rank = jnp.arange(sc.shape[0], dtype=jnp.int32) - starts[jnp.minimum(
        sc, ncells - 1)]
    ok = (sc < ncells) & (rank >= 0) & (rank < cap)
    cell_idx = jnp.where(ok, sc, ncells)   # out-of-bounds => dropped
    rank_idx = jnp.where(ok, rank, cap)
    table = jnp.full((ncells, cap), -1, jnp.int32)
    table = table.at[cell_idx, rank_idx].set(sr, mode="drop")
    return table


@partial(jax.jit, static_argnames=("ncells", "cap_s", "cap_u", "span_s",
                                   "span_u", "chunk"))
def _gbm_cell_counts(S: Regions, U: Regions, lb, width, ncells: int,
                     cap_s: int, cap_u: int, span_s: int, span_u: int,
                     chunk: int):
    s_lo, s_hi = S.lo[:, 0], S.hi[:, 0]
    u_lo, u_hi = U.lo[:, 0], U.hi[:, 0]
    ts = _bucketize(s_lo, s_hi, lb, width, ncells, span_s, cap_s)
    tu = _bucketize(u_lo, u_hi, lb, width, ncells, span_u, cap_u)

    nchunks = ncells // chunk
    ts = ts.reshape(nchunks, chunk, cap_s)
    tu = tu.reshape(nchunks, chunk, cap_u)
    cell_ids = jnp.arange(ncells, dtype=jnp.int32).reshape(nchunks, chunk)

    def per_chunk(carry, args):
        tsc, tuc, cid = args                     # (chunk,cap_s) etc.
        sl = s_lo[jnp.maximum(tsc, 0)]
        sh = s_hi[jnp.maximum(tsc, 0)]
        ul = u_lo[jnp.maximum(tuc, 0)]
        uh = u_hi[jnp.maximum(tuc, 0)]
        vs = tsc >= 0
        vu = tuc >= 0
        ov = (sl[:, :, None] < uh[:, None, :]) & \
             (ul[:, None, :] < sh[:, :, None])
        # first-overlapped-cell dedup: count only where the cell owns
        # max(s.lo, u.lo)
        own = _cell_of(jnp.maximum(sl[:, :, None], ul[:, None, :]),
                       lb, width, ncells) == cid[:, None, None]
        ok = ov & own & vs[:, :, None] & vu[:, None, :]
        return carry, jnp.sum(ok, dtype=jnp.int32)

    _, per_chunk_counts = jax.lax.scan(per_chunk, 0, (ts, tu, cell_ids))
    return per_chunk_counts


def _capacities(lo, hi, lb, width, ncells):
    """Host-side pre-pass: max cells per region, max regions per cell."""
    c0, c1 = _cell_spans(jnp.asarray(lo), jnp.asarray(hi),
                         jnp.float32(lb), jnp.float32(width), ncells)
    c0n, c1n = np.asarray(c0), np.asarray(c1)
    span = int((c1n - c0n).max()) + 1
    # occupancy per cell via difference array
    diff = np.bincount(c0n, minlength=ncells + 1).astype(np.int64)
    diff -= np.bincount(np.minimum(c1n + 1, ncells), minlength=ncells + 1)
    occ = np.cumsum(diff[:ncells])
    return span, max(int(occ.max()), 1)


def gbm_count(S: Regions, U: Regions, ncells: int = 3000,
              chunk: int | None = None) -> int:
    """Total K via grid matching.  ``ncells`` is the paper's tuning knob."""
    assert S.d == 1
    lb = float(min(jnp.min(S.lo), jnp.min(U.lo)))
    ub = float(max(jnp.max(S.hi), jnp.max(U.hi)))
    width = max((ub - lb) / ncells, 1e-30)
    span_s, cap_s = _capacities(S.lo[:, 0], S.hi[:, 0], lb, width, ncells)
    span_u, cap_u = _capacities(U.lo[:, 0], U.hi[:, 0], lb, width, ncells)
    if chunk is None:
        # keep the (chunk, cap_s, cap_u) compare block around ~2^22 elems
        chunk = max(1, min(ncells, (1 << 22) // max(cap_s * cap_u, 1)))
    while ncells % chunk:
        chunk -= 1
    counts = _gbm_cell_counts(S, U, jnp.float32(lb), jnp.float32(width),
                              ncells, cap_s, cap_u, span_s, span_u, chunk)
    return int(np.sum(np.asarray(counts), dtype=np.int64))
