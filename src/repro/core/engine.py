"""Unified MatchSpec → MatchPlan engine — one plan/compile/execute API.

The paper's deliverable is a *family* of interchangeable DDM matchers
(BFM, GBM, parallel SBM, the grid+SBM hybrid ``hsbm``, ITM) evaluated
under one harness; this module makes algorithm and backend choice a
**config value** instead of five divergent call paths:

    spec = MatchSpec(algo="sbm", backend="pallas", capacity="grow")
    plan = build_plan(spec, n_sub=S.n, n_upd=U.n, d=S.d)
    k = plan.count(S, U)
    res, k = plan.pairs(S, U)            # PairsResult (−1-padded slots)
    ids, cnt = plan.query(tree, opp, q_lo, q_hi)   # dynamic service path

``pairs()`` always returns a ``core.pairs.PairsResult`` — a
``DensePairs`` wrapper over the dense buffer on most paths, the lazy
``kernels.ops.CSRPairs`` view on the pallas csr emit route — so
consumers write one code path (``np.asarray`` or ``windows()``)
regardless of algo × backend × route.

A ``MatchSpec`` is a frozen, hashable description of *how* to match
(algorithm, backend, capacity policy, tile/block sizes, mesh).
``build_plan`` compiles it once for a problem shape ``(n_sub, n_upd, d)``
into a ``MatchPlan`` whose executables are jit-cached per plan: repeated
calls with the same shapes and resolved capacities never retrace (the
plan's ``traces`` counter is incremented only at trace time, so tests —
and users — can assert zero retraces in steady state).  All paths are
empty-set-safe: zero-region inputs yield count 0 and well-formed all-−1
buffers without touching the device kernels.

Backends
--------
``xla``          pure-jnp reference implementations (``brute``, ``grid``,
                 ``sbm``, ``itm``) — always available.
``pallas``       Mosaic TPU kernels where one exists for the algorithm
                 (BFM tile count/mask/pairs, SBM sweep count, and the
                 fused two-pass emit kernel for SBM pair enumeration);
                 stages without a kernel (sorts, tree walks,
                 verification) run on XLA.  ``interpret=True`` runs the
                 kernel bodies on CPU (tests / CI smoke).
``distributed``  multi-device parallel SBM under ``shard_map`` (paper
                 §4), now the full engine API: ``count()`` (distributed
                 sample sort + collective prefix), ``pairs()`` (sharded
                 two-pass emit — per-device exact counts, a global
                 exclusive offset scan via one ``all_gather``, fully
                 parallel per-device slot-range emit into a globally
                 indexed buffer, d-dim overlap filtered at emit time),
                 and ``query()`` (tree replicated, query batch sharded).
                 Results are set-identical to ``xla`` at any mesh size;
                 only ``mask()`` remains local-only (a dense (n, m)
                 matrix has no sharded consumer).

Capacity policies (static buffer sizing for ``pairs()``/``query()``)
--------------------------------------------------------------------
``exact``  run the cheap counting pass first, size the buffer to exactly
           K.  Never truncates; retraces whenever K changes.
``fixed``  caller-supplied ``max_pairs``; truncation reports the true K.
           Never retraces.
``grow``   grow-by-doubling: power-of-two buffer, re-executed doubled on
           overflow and memoized, so steady-state churn reuses one
           compiled kernel and a stream of calls retraces O(lg max K)
           times total.  Floored at ``max_pairs`` when given.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import brute, grid, itm, sbm
from .pairs import DensePairs, PairsResult, ShardedPairs
from .regions import Regions

Array = jax.Array

ALGOS = ("bfm", "gbm", "sbm", "sbm_chunked", "sbm_binary", "hsbm", "itm")
BACKENDS = ("xla", "pallas", "distributed")
CAPACITY_POLICIES = ("exact", "fixed", "grow")
_HSBM_STATIC_ARGNAMES = ("ncells", "cap_s", "suf_s", "cap_u", "suf_u",
                         "max_pairs")

# Hook point for the static auditor (repro.analysis): when set, every
# per-plan jitted executable is routed through the hook at creation time
# so the auditor can record the underlying function and its concrete
# call arguments, then re-trace them abstractly with ``jax.make_jaxpr``.
# ``None`` in production — the hot path pays one global read per
# *executable creation*, never per call.
_JIT_CAPTURE_HOOK = None


def _pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


@dataclasses.dataclass(frozen=True)
class MatchSpec:
    """Frozen, hashable description of *how* to match.

    ``algo``/``backend``/``capacity`` select the path; the remaining
    fields are per-algorithm tunables (the paper's knobs) with the same
    defaults the old entry points used.  Hashability is what lets
    ``build_plan`` memoize compiled plans.
    """

    algo: str = "sbm"
    backend: str = "xla"
    capacity: str = "exact"
    d: int | None = None           # declared dimensionality (optional)
    max_pairs: int | None = None   # fixed cap / grow floor
    tile: int = 4096               # BFM xla U-tile
    ncells: int = 3000             # GBM grid cells
    hsbm_ncells: int | None = None  # hsbm grid override (None=measured)
    p: int = 8                     # chunked-SBM segments
    swap: str = "auto"             # ITM build-side policy
    ts: int = 256                  # Pallas BFM tile sizes
    tu: int = 256
    block: int = 2048              # Pallas sweep/emit block
    interpret: bool = False        # Pallas interpret mode (CPU)
    emit_route: str = "auto"       # Pallas emit regime (below)
    emit_budget: int | None = None  # emit VMEM byte budget (None=default)
    overprovision: float = 2.5     # distributed bucket slack
    mesh: Any = None               # jax.sharding.Mesh for distributed

    def __post_init__(self):
        if self.algo not in ALGOS:
            raise ValueError(f"algo must be one of {ALGOS}, got {self.algo}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {self.backend}")
        if self.capacity not in CAPACITY_POLICIES:
            raise ValueError(
                f"capacity must be one of {CAPACITY_POLICIES}, "
                f"got {self.capacity}")
        if self.capacity == "fixed" and self.max_pairs is None:
            raise ValueError("capacity='fixed' requires max_pairs")
        if self.emit_route not in ("auto", "resident", "streaming", "csr",
                                   "xla"):
            raise ValueError(
                "emit_route must be one of ('auto', 'resident', "
                f"'streaming', 'csr', 'xla'), got {self.emit_route}")
        if self.d is not None and self.d < 1:
            raise ValueError(f"d must be >= 1, got {self.d}")
        if self.emit_route == "csr" and self.d is not None and self.d > 1:
            raise ValueError(
                "emit_route='csr' returns a lazy CSRPairs view, but d > 1 "
                "verification gathers from a dense dim-0 candidate "
                "buffer; use emit_route='auto'/'streaming'/'xla' "
                f"for d={self.d}")


class MatchPlan:
    """Compiled matcher for one ``(spec, n_sub, n_upd, d)`` problem shape.

    Executables are built lazily on first use and cached on the plan;
    ``traces`` counts device-side (re)traces — steady-state calls with
    stable shapes and capacities leave it unchanged.
    """

    def __init__(self, spec: MatchSpec, n_sub: int, n_upd: int, d: int):
        if d < 1:
            raise ValueError(f"d must be >= 1, got {d}")
        if spec.d is not None and spec.d != d:
            raise ValueError(
                f"spec declares d={spec.d} but the plan is built for "
                f"d={d}")
        if spec.emit_route == "csr" and d > 1:
            raise ValueError(
                "emit_route='csr' returns a lazy CSRPairs view, but "
                "d > 1 verification gathers from a dense dim-0 candidate "
                "buffer; use emit_route='auto'/'streaming'/'xla' "
                f"for d={d}")
        self.spec = spec
        self.n_sub = int(n_sub)
        self.n_upd = int(n_upd)
        self.d = int(d)
        self.traces = 0
        # one entry per device-side (re)trace, in order: the executable
        # name that traced.  ``analysis.no_retrace`` reports these when
        # a steady-state region of code retraces unexpectedly.
        self.trace_log: list[str] = []
        self._exec: dict[str, Any] = {}
        self._cap: int | None = None        # memoized output capacity
        self._cand_cap: int | None = None   # memoized dim-0 candidate cap
        self._cap_dev: int | None = None    # memoized per-device emit cap
        self._query_cap = max(spec.max_pairs or 1, 1)

    def __repr__(self) -> str:
        s = self.spec
        return (f"MatchPlan(algo={s.algo}, backend={s.backend}, "
                f"capacity={s.capacity}, n_sub={self.n_sub}, "
                f"n_upd={self.n_upd}, d={self.d})")

    # -- plumbing -----------------------------------------------------------
    def _check(self, S: Regions, U: Regions):
        if (S.n, U.n) != (self.n_sub, self.n_upd) or S.d != self.d:
            raise ValueError(
                f"plan compiled for (n_sub={self.n_sub}, n_upd={self.n_upd},"
                f" d={self.d}); got (n_sub={S.n}, n_upd={U.n}, d={S.d})")

    def _jitted(self, name: str, fn, static_argnames=()):
        """Per-plan jitted executable with a trace counter."""
        cached = self._exec.get(name)
        if cached is None:
            plan = self

            def counting(*args, **kw):
                plan.traces += 1
                plan.trace_log.append(name)
                return fn(*args, **kw)

            cached = jax.jit(counting, static_argnames=static_argnames)
            if _JIT_CAPTURE_HOOK is not None:
                cached = _JIT_CAPTURE_HOOK(self, name, fn, static_argnames,
                                           cached)
            self._exec[name] = cached
        return cached

    def _resolve_cap(self, exact_k: int) -> int:
        """Output-buffer capacity under the plan's policy."""
        pol = self.spec.capacity
        if pol == "fixed":
            return max(self.spec.max_pairs, 1)
        if pol == "exact":
            self._cap = max(exact_k, 1)
            return self._cap
        cap = _pow2(max(exact_k, self.spec.max_pairs or 1, 1))
        self._cap = max(self._cap or 1, cap)
        return self._cap

    def _resolve_cand_cap(self, exact_c: int) -> int:
        """Dim-0 candidate capacity (must hold EVERY dim-0 overlap)."""
        if self.spec.capacity == "grow":
            self._cand_cap = max(self._cand_cap or 1, _pow2(max(exact_c, 1)))
            return self._cand_cap
        self._cand_cap = max(exact_c, 1)
        return self._cand_cap

    def _resolve_cap_dev(self, need: int) -> int:
        """Per-device emit-buffer capacity for the distributed backend.

        ``need`` is the max over per-device dim-0 pair totals (from the
        sharded pass-1 counts).  ``grow`` memoizes a monotone
        power-of-two so steady-state churn reuses one compiled emit;
        ``fixed`` at d == 1 uses ``max_pairs`` per device — a static,
        data-independent shape that never retraces (truncation stays
        exact: the assembled prefix is the same first-``max_pairs``
        slice a global emit would keep); everything else sizes exactly
        (d > 1 must hold every dim-0 candidate so the verified K stays
        exact, matching the old exactly-sized candidate buffer).
        """
        need = max(need, 1)
        if self.spec.capacity == "grow":
            self._cap_dev = max(self._cap_dev or 1, _pow2(need))
            return self._cap_dev
        if self.spec.capacity == "fixed" and self.d == 1:
            return max(self.spec.max_pairs, 1)
        return need

    def _project(self, R: Regions) -> Regions:
        return Regions(R.lo[:, :1], R.hi[:, :1])

    # -- counting -----------------------------------------------------------
    def count(self, S: Regions, U: Regions) -> int:
        """Exact number of overlapping (subscription, update) pairs."""
        self._check(S, U)
        spec = self.spec
        if S.n == 0 or U.n == 0:
            return 0
        if spec.backend == "distributed":
            if self.d == 1:
                return self._count_distributed(S, U)
            # d > 1 falls through to the generic match-then-verify
            # count, whose _pairs_impl dispatches to the sharded emit
        elif spec.algo == "bfm":
            return self._count_bfm(S, U)
        elif self.d == 1:
            return self._count_1d(S, U)
        # d > 1: counting requires pair identity (match-then-verify);
        # the count is exact regardless of the 1-slot output buffer.
        _, k = self._pairs_impl(S, U, out_cap=1)
        return k

    def _count_bfm(self, S: Regions, U: Regions) -> int:
        spec = self.spec
        if spec.backend == "pallas":
            from ..kernels import ops
            return ops.bfm_count_pallas(S, U, ts=spec.ts, tu=spec.tu,
                                        interpret=spec.interpret)
        f = self._jitted(
            "bfm_count",
            functools.partial(brute.bfm_count_per_sub, tile=spec.tile))
        return int(np.sum(np.asarray(f(S, U)), dtype=np.int64))

    def _count_1d(self, S: Regions, U: Regions) -> int:
        spec = self.spec
        algo = spec.algo
        if algo == "hsbm":
            return self._count_hsbm(S, U)
        if spec.backend == "pallas" and algo in ("sbm", "sbm_chunked"):
            from ..kernels import ops
            return ops.sbm_count_pallas(S, U, block=spec.block,
                                        interpret=spec.interpret)
        if algo == "sbm":
            f = self._jitted("sbm_contribs", sbm._sweep_contribs)
            c = f(S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0])
            return int(np.sum(np.asarray(c), dtype=np.int64))
        if algo == "sbm_chunked":
            f = self._jitted("sbm_chunked", sbm._chunked_contribs,
                             static_argnames=("p",))
            c = f(S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0], p=spec.p)
            return int(np.sum(np.asarray(c), dtype=np.int64))
        if algo == "sbm_binary":
            f = self._jitted("sbm_per_sub", sbm.sbm_count_per_sub)
            return int(np.sum(np.asarray(f(S, U)), dtype=np.int64))
        if algo == "itm":
            build_on_S = (S.n <= U.n if spec.swap == "auto"
                          else spec.swap == "S")
            T = itm.build_tree(S if build_on_S else U)
            Q = U if build_on_S else S
            f = self._jitted("itm_counts", itm.itm_query_counts)
            c = f(T, Q.lo[:, 0], Q.hi[:, 0])
            return int(np.sum(np.asarray(c), dtype=np.int64))
        if algo == "gbm":
            return grid.gbm_count(S, U, ncells=spec.ncells)
        raise AssertionError(algo)

    def _hsbm_geom(self, S0: Regions, U0: Regions):
        """Measure (or override) the hybrid grid geometry for this call.

        Host-side NumPy over the dim-0 coordinates; the measured statics
        are rounded to coarse quanta (``grid.hsbm_geometry``), so
        same-distribution churn maps to one geometry and the plan's
        executables never retrace in steady state.
        """
        return grid.hsbm_geometry(S0.lo[:, 0], S0.hi[:, 0],
                                  U0.lo[:, 0], U0.hi[:, 0],
                                  ncells=self.spec.hsbm_ncells)

    def _count_hsbm(self, S: Regions, U: Regions) -> int:
        """Exact K from the hybrid pass 1 alone (no emission).

        Pass 1's unclipped per-emitter counts sum to K in host int64 —
        identical math on both backends; only the jit wrapper differs
        (plan-counted for xla, the shared module executable for pallas
        so the benchmark and the engine hit one compile cache).
        """
        spec = self.spec
        S0, U0 = self._project(S), self._project(U)
        g = self._hsbm_geom(S0, U0)
        args = (S0.lo[:, 0], S0.hi[:, 0], U0.lo[:, 0], U0.hi[:, 0],
                jnp.float32(g.lb), jnp.float32(g.width))
        if spec.backend == "pallas":
            from ..kernels import ops
            counts = ops._hsbm_tables(*args, max_pairs=1, **g.statics())[3]
        else:
            f = self._jitted("hsbm_tables", sbm._hsbm_phase1,
                             static_argnames=_HSBM_STATIC_ARGNAMES)
            counts = f(*args, max_pairs=1, **g.statics())[3]
        return int(np.sum(np.asarray(counts), dtype=np.int64))

    def _count_distributed(self, S: Regions, U: Regions) -> int:
        spec = self.spec
        if spec.algo not in ("sbm", "sbm_chunked", "sbm_binary"):
            raise ValueError(
                "distributed backend implements parallel SBM; "
                f"algo={spec.algo!r} is not supported")
        from .distributed import _distributed_count
        return _distributed_count(S, U, mesh=spec.mesh,
                                  overprovision=spec.overprovision)

    # -- pair enumeration ---------------------------------------------------
    def pairs(self, S: Regions, U: Regions):
        """Enumerate overlaps: ``(PairsResult, count)``.

        The first element is always a ``core.pairs.PairsResult`` with
        capacity resolved by the plan's policy; ``count`` (also exposed
        as ``result.count``) is the exact K (python int) even when a
        fixed buffer truncates.  Dense-emitting paths return a
        ``DensePairs`` wrapper (``np.asarray``/slicing behave exactly
        like the raw buffer they used to return); the pallas backend's
        ``csr`` emit route (chosen by the byte policy past n+m ≈ 2e6,
        or pinned via ``MatchSpec.emit_route``) returns the lazy
        ``kernels.ops.CSRPairs`` subclass — device memory stays
        O(n+m), and any slot window decodes on demand
        (``result.decode(a, b)`` / ``result.windows()``),
        bit-identical to the dense buffer's slice.  The capacity
        policies are unaffected — every route reports exact K, and
        ``grow``/``exact`` re-emit at the resolved capacity.
        """
        self._check(S, U)
        spec = self.spec
        if S.n == 0 or U.n == 0:
            cap = self._resolve_cap(0)
            return DensePairs(jnp.full((cap, 2), -1, jnp.int32), 0), 0
        if spec.capacity == "exact":
            # the counting pass runs only when no capacity is memoized
            # yet; steady-state calls emit directly (every path reports
            # the exact K) and re-emit once if K drifted.
            cap = self._cap
            if cap is None:
                cap = self._resolve_cap(self.count(S, U))
            pairs, k = self._pairs_impl(S, U, out_cap=cap)
            if max(k, 1) != cap:
                cap = self._resolve_cap(k)
                pairs, k = self._pairs_impl(S, U, out_cap=cap)
            return self._wrap_pairs(pairs, k)
        if spec.capacity == "fixed":
            pairs, k = self._pairs_impl(S, U,
                                        out_cap=self._resolve_cap(0))
            return self._wrap_pairs(pairs, k)
        # grow-by-doubling: every path reports the exact K, so at most
        # one re-execution with the doubled (power-of-two) buffer.
        cap = self._resolve_cap(0)
        pairs, k = self._pairs_impl(S, U, out_cap=cap)
        if k > cap:
            cap = self._resolve_cap(k)
            pairs, k = self._pairs_impl(S, U, out_cap=cap)
        return self._wrap_pairs(pairs, k)

    @staticmethod
    def _wrap_pairs(pairs, k: int):
        """Uniform ``(PairsResult, count)`` return for ``pairs()``."""
        if isinstance(pairs, PairsResult):
            return pairs, k
        return DensePairs(pairs, k), k

    def _pairs_impl(self, S: Regions, U: Regions, out_cap: int):
        """(pairs, exact K) with a caller-resolved output capacity."""
        spec = self.spec
        algo = spec.algo
        if spec.backend == "distributed":
            return self._pairs_distributed(S, U, out_cap)
        if algo == "bfm" or algo == "gbm":
            # GBM degenerates to BFM for enumeration (paper: per-cell
            # matching IS brute force; pair identity needs no grid).
            return self._pairs_bfm(S, U, out_cap)
        if algo in ("sbm", "sbm_chunked", "sbm_binary"):
            cand, k = self._pairs_sbm_dim0(
                S, U, out_cap if self.d == 1 else self._cand_bound(S, U))
        elif algo == "hsbm":
            cand, k = self._pairs_hsbm_dim0(
                S, U, out_cap if self.d == 1 else self._cand_bound(S, U))
        elif algo == "itm":
            cand, k = self._pairs_itm_dim0(
                S, U, out_cap if self.d == 1 else self._cand_bound(S, U))
        else:
            raise AssertionError(algo)
        if self.d == 1:
            return cand, k
        f = self._jitted("verify", sbm_verify_dims,
                         static_argnames=("max_pairs",))
        pairs, count = f(S, U, cand, max_pairs=out_cap)
        return pairs, int(count)

    def _cand_bound(self, S: Regions, U: Regions) -> int:
        """Exact dim-0 candidate count (binary-search per-sub counts)."""
        f = self._jitted("cand_per_sub", sbm.sbm_count_per_sub)
        c = f(self._project(S), self._project(U))
        return self._resolve_cand_cap(
            int(np.sum(np.asarray(c), dtype=np.int64)))

    def _pairs_bfm(self, S: Regions, U: Regions, out_cap: int):
        spec = self.spec
        if spec.backend == "pallas":
            from ..kernels import ops
            pairs, count = ops.bfm_pairs_pallas(
                S, U, out_cap, ts=spec.ts, tu=spec.tu,
                interpret=spec.interpret)
            return pairs, int(count)
        f = self._jitted("bfm_pairs", brute.bfm_pairs,
                         static_argnames=("max_pairs",))
        pairs, count = f(S, U, max_pairs=out_cap)
        return pairs, int(count)

    def validate_pairs(self, pairs, count: int | None = None) -> None:
        """Host-side sanity check of a ``pairs()`` result buffer.

        Raises ``ValueError`` naming the offending slots, their (s, u)
        values, the valid ranges, and this plan's ``repr()`` — the
        dynamic companion of the static auditor's index checks.  A pad
        row is all −1; any partially-padded row is also an error.

        ``PairsResult`` inputs are consumed window-by-window through
        the ``windows()`` contract, so a lazy CSR view is validated
        without ever materializing the dense ``(cap, 2)`` buffer.
        """
        if isinstance(pairs, PairsResult):
            problems: list[str] = []
            non_pad = 0
            cap = pairs.cap
            for w0, win in pairs.windows():
                errs = describe_pair_range_errors(win, self.n_upd,
                                                  self.n_sub)
                problems.extend(f"{e} [window at slot {w0}]"
                                for e in errs)
                non_pad += int(np.sum(win[:, 0] >= 0))
        else:
            arr = np.asarray(pairs)
            problems = describe_pair_range_errors(arr, self.n_upd,
                                                  self.n_sub)
            non_pad = int(np.sum(arr[:, 0] >= 0))
            cap = arr.shape[0]
        if count is not None:
            want = min(count, cap)
            if non_pad != want:
                problems.append(
                    f"buffer holds {non_pad} non-pad rows but the "
                    f"reported count is {count} (capacity {cap})")
        if problems:
            raise ValueError("invalid pair buffer: "
                             + "; ".join(problems) + f"; plan={self!r}")

    def emit_route(self) -> str | None:
        """The emit regime ``pairs()`` will take on the pallas backend.

        Resolves the spec's ``emit_route`` pin, or applies the byte-budget
        policy (``kernels.ops.choose_emit_route``) to this plan's problem
        shape under ``emit_budget``.  ``None`` for non-pallas backends and
        for algorithms that do not reach the two-pass emit kernel.  For
        d > 1 plans ``auto`` never resolves to ``csr`` — the verify pass
        gathers from the dense dim-0 candidate buffer — and a pinned
        ``csr`` is rejected at spec/plan construction.  For
        ``algo='hsbm'`` under ``auto`` the answer is ``None``: the
        route depends on the *measured* grid geometry, not on (n, m)
        alone — tests read ``kernels.ops.last_emit_route()`` after a
        ``pairs()`` call instead.
        """
        spec = self.spec
        if (spec.backend != "pallas"
                or spec.algo not in ("sbm", "sbm_chunked", "sbm_binary",
                                     "hsbm")):
            return None
        if spec.emit_route != "auto":
            return spec.emit_route
        if spec.algo == "hsbm":
            return None
        from ..kernels import ops
        return ops.choose_emit_route(self.n_sub, self.n_upd,
                                     block=spec.block,
                                     budget=spec.emit_budget,
                                     dense_only=self.d > 1)

    def _pairs_sbm_dim0(self, S: Regions, U: Regions, cap: int):
        spec = self.spec
        S0, U0 = self._project(S), self._project(U)
        if spec.backend == "pallas":
            from ..kernels import ops
            return ops.twopass_pairs_pallas(S0, U0, cap, block=spec.block,
                                            interpret=spec.interpret,
                                            route=spec.emit_route,
                                            budget=spec.emit_budget,
                                            dense_only=self.d > 1)
        f = self._jitted("twopass_emit", sbm._twopass_emit,
                         static_argnames=("max_pairs",))
        pairs, cnt_a, cnt_b = f(S0.lo[:, 0], S0.hi[:, 0],
                                U0.lo[:, 0], U0.hi[:, 0], max_pairs=cap)
        k = int(np.sum(np.asarray(cnt_a), dtype=np.int64)
                + np.sum(np.asarray(cnt_b), dtype=np.int64))
        return pairs, k

    def _pairs_hsbm_dim0(self, S: Regions, U: Regions, cap: int):
        """Hybrid grid+SBM dim-0 enumeration (measured geometry)."""
        spec = self.spec
        S0, U0 = self._project(S), self._project(U)
        if spec.backend == "pallas":
            from ..kernels import ops
            g = self._hsbm_geom(S0, U0)
            return ops.hsbm_pairs_pallas(S0, U0, cap, geom=g,
                                         block=spec.block,
                                         interpret=spec.interpret,
                                         route=spec.emit_route,
                                         budget=spec.emit_budget,
                                         dense_only=self.d > 1)
        g = self._hsbm_geom(S0, U0)
        f = self._jitted("hsbm_emit", sbm._hsbm_emit,
                         static_argnames=_HSBM_STATIC_ARGNAMES)
        pairs, counts = f(S0.lo[:, 0], S0.hi[:, 0], U0.lo[:, 0],
                          U0.hi[:, 0], jnp.float32(g.lb),
                          jnp.float32(g.width), max_pairs=cap,
                          **g.statics())
        k = int(np.sum(np.asarray(counts), dtype=np.int64))
        return pairs, k

    def _pairs_itm_dim0(self, S: Regions, U: Regions, cap: int):
        T = itm.build_tree(self._project(S))
        fc = self._jitted("itm_counts", itm.itm_query_counts)
        counts = fc(T, U.lo[:, 0], U.hi[:, 0])
        per_q = max(int(np.max(np.asarray(counts), initial=0)), 1)
        if self.spec.capacity == "grow":   # bound retraces under drift
            per_q = _pow2(per_q)
        fp = self._jitted("itm_flatten", itm_flatten_pairs,
                          static_argnames=("per_q", "cap"))
        cand = fp(T, U.lo[:, 0], U.hi[:, 0], per_q=per_q, cap=cap)
        k = int(np.sum(np.asarray(counts), dtype=np.int64))
        return cand, k

    def _pairs_distributed(self, S: Regions, U: Regions, out_cap: int):
        """Sharded two-pass emit with per-device slot-bound buffers.

        Pass 1 (``dist_pairs_pass1``) runs the distributed sample sort
        of both lo streams *with an index payload* — the sort
        permutations come out of the same ``all_to_all`` the counting
        path uses, no replicated argsort — plus the sharded exact
        per-emitter counts.  The host reduces the counts twice: the
        int64 sum is the exact K, and the per-device maxima size the
        static per-device emit capacity (``_resolve_cap_dev``).  Pass 2
        (``dist_pairs_emit``) emits each device's pairs into its own
        ``(cap_dev, 2)`` buffer — O(K/P + P) per device, no global-cap
        scan, no O(cap) psum — and the result stays sharded inside a
        ``ShardedPairs`` until a consumer asks for the dense view.
        d > 1 filters the remaining dimensions at emit time and
        compacts locally; K is then the summed per-device verified
        totals (exact: ``cap_dev`` holds every dim-0 candidate).
        """
        spec = self.spec
        if spec.algo not in ("sbm", "sbm_chunked", "sbm_binary"):
            raise ValueError(
                "distributed backend implements parallel SBM; "
                f"algo={spec.algo!r} is not supported")
        from . import distributed as dist
        mesh = dist.resolve_mesh(spec.mesh)
        nshards = int(np.prod(mesh.devices.shape))
        split_s = dist.sample_splitters(S.lo[:, 0], S.n, nshards)
        split_u = dist.sample_splitters(U.lo[:, 0], U.n, nshards)
        f1 = self._jitted("dist_pairs_pass1", dist._dist_pairs_pass1,
                          static_argnames=("cap_s", "cap_u", "nshards",
                                           "mesh"))
        counts, s_sorted, perm_s, u_sorted, perm_u, ovf = f1(
            S.lo, S.hi, U.lo, U.hi, split_s, split_u,
            cap_s=dist.bucket_cap(S.n, nshards, spec.overprovision),
            cap_u=dist.bucket_cap(U.n, nshards, spec.overprovision),
            nshards=nshards, mesh=mesh)
        if int(np.asarray(ovf)) > 0:
            raise OverflowError(
                "distributed SBM bucket overflow; raise overprovision")
        counts_h = np.asarray(counts)
        k0 = int(np.sum(counts_h, dtype=np.int64))
        dev_tot = counts_h.reshape(nshards, -1).sum(axis=1,
                                                    dtype=np.int64)
        cap_dev = self._resolve_cap_dev(int(dev_tot.max(initial=0)))
        f2 = self._jitted("dist_pairs_emit", dist._dist_pairs_emit,
                          static_argnames=("cap_dev", "nshards", "mesh"))
        bufs, ver = f2(S.lo, S.hi, U.lo, U.hi, u_sorted, s_sorted,
                       perm_s, perm_u, cap_dev=cap_dev, nshards=nshards,
                       mesh=mesh)
        ver_h = np.asarray(ver, dtype=np.int64)
        k = k0 if self.d == 1 else int(ver_h.sum())
        return ShardedPairs(bufs, ver_h, out_cap, k), k

    # -- masks --------------------------------------------------------------
    def mask(self, S: Regions, U: Regions) -> Array:
        """(n, m) boolean overlap mask (algorithm-independent)."""
        self._check(S, U)
        spec = self.spec
        if spec.backend == "distributed":
            raise NotImplementedError(
                "distributed backend supports count/pairs/query; a dense "
                "(n, m) mask is not sharded — use backend='xla'/'pallas'")
        if S.n == 0 or U.n == 0:
            return jnp.zeros((S.n, U.n), jnp.bool_)
        if spec.backend == "pallas":
            from ..kernels import ops
            return ops.bfm_mask_pallas(S, U, ts=spec.ts, tu=spec.tu,
                                       interpret=spec.interpret)
        f = self._jitted("mask", brute.bfm_mask)
        return f(S, U)

    # -- dynamic-service batched query (paper §3) ---------------------------
    def query(self, tree: itm.ITree, opp: Regions, q_lo: Array,
              q_hi: Array):
        """Verified d-dim overlap ids for a batch of query boxes.

        ``tree`` indexes dim 0 of the ``opp`` regions; ``q_lo``/``q_hi``
        are (b, d).  Returns ``(ids (b, cap) −1-padded, counts (b,))``
        with ``cap`` resolved by the capacity policy (``grow`` memoizes
        a power-of-two cap so steady-state churn reuses one compiled
        query kernel — the DDMService path).  Under
        ``backend="distributed"`` the tree and ``opp`` coordinates are
        replicated and the query batch is sharded over the mesh; the
        capacity is sized by a global max-count reduction over the
        gathered per-query counts, so every device compiles the same
        static shape.
        """
        b = int(q_lo.shape[0])
        if b == 0 or opp.n == 0:
            z = jnp.full((b, 1), -1, jnp.int32)
            return z, jnp.zeros((b,), jnp.int32)
        if self.spec.backend == "distributed":
            return self._query_distributed(tree, opp, q_lo, q_hi)
        fc = self._jitted("itm_counts", itm.itm_query_counts)
        counts0 = fc(tree, q_lo[:, 0], q_hi[:, 0])
        cap = self._resolve_query_cap(
            int(np.max(np.asarray(counts0), initial=0)))
        fq = self._jitted("itm_query_dd", itm.itm_query_pairs_dd,
                          static_argnames=("cap",))
        return fq(tree, opp.lo, opp.hi, q_lo, q_hi, cap=cap)

    def _resolve_query_cap(self, need: int) -> int:
        """Per-query id-buffer capacity under the plan's policy."""
        need = max(need, 1)
        pol = self.spec.capacity
        if pol == "fixed":
            return max(self.spec.max_pairs, 1)
        if pol == "exact":
            return need
        self._query_cap = max(self._query_cap, _pow2(need))
        return self._query_cap

    def _query_distributed(self, tree: itm.ITree, opp: Regions,
                           q_lo: Array, q_hi: Array):
        from . import distributed as dist
        mesh = dist.resolve_mesh(self.spec.mesh)
        nshards = int(np.prod(mesh.devices.shape))
        fc = self._jitted("dist_query_counts", dist._dist_query_counts,
                          static_argnames=("nshards", "mesh"))
        counts0 = fc(tree, q_lo[:, 0], q_hi[:, 0], nshards=nshards,
                     mesh=mesh)
        # global max-count reduction: one shared static capacity
        cap = self._resolve_query_cap(
            int(np.max(np.asarray(counts0), initial=0)))
        fq = self._jitted("dist_query", dist._dist_query,
                          static_argnames=("cap", "nshards", "mesh"))
        return fq(tree, opp.lo, opp.hi, q_lo, q_hi, cap=cap,
                  nshards=nshards, mesh=mesh)


# ---------------------------------------------------------------------------
# engine-level device helpers (shared by plans; jitted per plan)
# ---------------------------------------------------------------------------

def select_rows(rows: Array, keep: Array, cap: int) -> Array:
    """Rows where ``keep`` holds, −1-padded to ``cap`` (the engine's
    shared recompaction idiom: nonzero with a static size, then a
    guarded gather)."""
    sel = jnp.nonzero(keep, size=cap, fill_value=-1)[0]
    return jnp.where(sel[:, None] >= 0, rows[jnp.maximum(sel, 0)], -1)


def describe_pair_range_errors(arr: np.ndarray, m: int,
                               n: int | None = None,
                               max_report: int = 5) -> list[str]:
    """Human-readable index-range problems in a −1-padded pair buffer.

    ``arr`` is a host (cap, 2) int array; ``m``/``n`` are the update/
    subscription set sizes.  Returns one message per problem class,
    each naming up to ``max_report`` offending slots with their (s, u)
    values and the valid range — shared by ``MatchPlan.validate_pairs``
    and ``dd_match.pairs_to_set`` so a range failure is never a bare
    assertion.
    """
    def _offenders(slots):
        shown = ", ".join(
            f"slot {int(t)}: (s={int(arr[t, 0])}, u={int(arr[t, 1])})"
            for t in slots[:max_report])
        more = f", … {len(slots) - max_report} more" \
            if len(slots) > max_report else ""
        return shown + more

    problems: list[str] = []
    non_pad = arr[:, 0] >= 0
    bad_u = np.nonzero(non_pad & ((arr[:, 1] < 0) | (arr[:, 1] >= m)))[0]
    if bad_u.size:
        problems.append(
            f"{bad_u.size} update index(es) outside [0, {m}): "
            + _offenders(bad_u))
    if n is not None:
        bad_s = np.nonzero(non_pad & (arr[:, 0] >= n))[0]
        if bad_s.size:
            problems.append(
                f"{bad_s.size} subscription index(es) outside [0, {n}): "
                + _offenders(bad_s))
    half_pad = np.nonzero(~non_pad & (arr[:, 1] >= 0))[0]
    if half_pad.size:
        problems.append(
            f"{half_pad.size} half-padded row(s) (s is −1 pad but u is "
            "not): " + _offenders(half_pad))
    return problems


def sbm_verify_dims(S: Regions, U: Regions, cand: Array, max_pairs: int):
    """Filter dim-0 candidate pairs on dimensions 1..d-1, recompact."""
    s_idx, u_idx = cand[:, 0], cand[:, 1]
    valid = s_idx >= 0
    si = jnp.maximum(s_idx, 0)
    ui = jnp.maximum(u_idx, 0)
    ok = jnp.all(
        jnp.logical_and(S.lo[si, 1:] < U.hi[ui, 1:],
                        U.lo[ui, 1:] < S.hi[si, 1:]), axis=-1)
    ok = ok & valid
    count = jnp.sum(ok, dtype=jnp.int32)
    return select_rows(cand, ok, max_pairs), count


def itm_flatten_pairs(T: itm.ITree, q_lo: Array, q_hi: Array, per_q: int,
                      cap: int) -> Array:
    """Tree-walk all queries, flatten (query, id) hits into (cap, 2)."""
    ids, _ = itm.itm_query_pairs(T, q_lo, q_hi, per_q)
    nq = ids.shape[0]
    u_idx = jnp.broadcast_to(
        jnp.arange(nq, dtype=jnp.int32)[:, None], ids.shape)
    rows = jnp.stack([ids.ravel(), u_idx.ravel()], axis=1)
    return select_rows(rows, (ids >= 0).ravel(), cap)


@functools.lru_cache(maxsize=256)
def build_plan(spec: MatchSpec, n_sub: int, n_upd: int, d: int,
               key: Any = None) -> MatchPlan:
    """Compile ``spec`` for a problem shape; memoized on all arguments.

    Returns the same ``MatchPlan`` (with its warm jit caches and resolved
    capacities) for repeated identical requests — plan-once-call-many is
    the intended usage, and the deprecation shims lean on this cache.

    ``key`` is a namespace hook: plans whose memoized state (grow
    capacities, trace history) must not be shared across otherwise
    identical requests pass a distinct hashable key.  The serving layer
    uses ``key=(server_id, tenant)`` so every ``(tenant, MatchSpec)``
    pair gets exactly one plan whose capacity ladder tracks that
    tenant's own churn.
    """
    return MatchPlan(spec, n_sub, n_upd, d)
