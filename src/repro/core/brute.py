"""Brute-Force Matching (BFM) — paper Algorithm 2, vectorized.

The paper's doubly-nested ``Intersect-1D`` loop becomes a tiled all-pairs
broadcast compare: embarrassingly parallel on OpenMP threads there, on VPU
lanes here.  ``U`` is processed in tiles so the (n × tile) overlap mask is
the only O(n·m) intermediate and its size is bounded.

The Pallas TPU kernel for the same computation lives in
``repro.kernels.bfm`` — this module is the pure-jnp reference and the small-
problem fast path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .regions import Regions

Array = jax.Array


def _mask_block(s_lo, s_hi, u_lo, u_hi) -> Array:
    """(n, m) overlap mask for d-dim regions. Inputs (n,d)/(m,d)."""
    # (n, 1, d) vs (1, m, d) -> (n, m, d) -> all over d
    ok = jnp.logical_and(s_lo[:, None, :] < u_hi[None, :, :],
                         u_lo[None, :, :] < s_hi[:, None, :])
    return jnp.all(ok, axis=-1)


@jax.jit
def bfm_mask(S: Regions, U: Regions) -> Array:
    """Full (n, m) boolean overlap mask (small problems / oracle)."""
    return _mask_block(S.lo, S.hi, U.lo, U.hi)


@partial(jax.jit, static_argnames=("tile",))
def bfm_count_per_sub(S: Regions, U: Regions, tile: int = 4096) -> Array:
    """Per-subscription overlap counts K_s, computed in U-tiles.

    Returns int32 (n,).  Total K = sum (done by the caller in int64 —
    XLA int32 would overflow at paper scale).
    """
    m = U.n
    pad = (-m) % tile
    u_lo = jnp.pad(U.lo, ((0, pad), (0, 0)), constant_values=jnp.inf)
    u_hi = jnp.pad(U.hi, ((0, pad), (0, 0)), constant_values=-jnp.inf)
    u_lo = u_lo.reshape(-1, tile, U.d)
    u_hi = u_hi.reshape(-1, tile, U.d)

    def body(carry, uw):
        ulo, uhi = uw
        mask = _mask_block(S.lo, S.hi, ulo, uhi)
        return carry + jnp.sum(mask, axis=1, dtype=jnp.int32), None

    init = jnp.zeros((S.n,), jnp.int32)
    counts, _ = jax.lax.scan(body, init, (u_lo, u_hi))
    return counts


def bfm_count(S: Regions, U: Regions, tile: int = 4096) -> int:
    """Total number of overlapping (s, u) pairs (python int, exact)."""
    import numpy as np

    return int(np.sum(np.asarray(bfm_count_per_sub(S, U, tile=tile)),
                      dtype=np.int64))


@partial(jax.jit, static_argnames=("max_pairs",))
def bfm_pairs(S: Regions, U: Regions, max_pairs: int):
    """Enumerate overlapping pairs into a static-capacity buffer.

    Returns ``(pairs, count)`` where ``pairs`` is int32 (max_pairs, 2)
    filled with (s_idx, u_idx) and padded with -1; ``count`` is the true
    number of overlaps (may exceed max_pairs — caller checks overflow).
    Report-exactly-once comes for free: each (s, u) cell of the mask is a
    distinct pair (paper §2 'reporting' requirement).
    """
    mask = _mask_block(S.lo, S.hi, U.lo, U.hi)
    count = jnp.sum(mask, dtype=jnp.int32)
    flat_idx = jnp.nonzero(mask.ravel(), size=max_pairs, fill_value=-1)[0]
    s_idx = jnp.where(flat_idx >= 0, flat_idx // U.n, -1).astype(jnp.int32)
    u_idx = jnp.where(flat_idx >= 0, flat_idx % U.n, -1).astype(jnp.int32)
    return jnp.stack([s_idx, u_idx], axis=1), count
