"""Legacy DDM matching entry points — deprecation shims over the engine.

The d-dimensional matching implementation now lives in
``repro.core.engine`` behind the plan/compile/execute API::

    spec = MatchSpec(algo="sbm", backend="xla", capacity="fixed",
                     max_pairs=cap)
    plan = build_plan(spec, n_sub=S.n, n_upd=U.n, d=S.d)
    pairs, k = plan.pairs(S, U)

``match_count`` / ``match_pairs`` remain as thin shims (one
``DeprecationWarning`` each, then a plan-cache hit) so examples and old
benchmarks keep working mid-migration — see ``docs/API.md`` for the
migration table.  ``block_mask`` and ``pairs_to_set`` are plain helpers,
not deprecated.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np

from .engine import ALGOS, MatchSpec, build_plan
from .regions import Regions

Array = jax.Array

_DEPRECATION = ("%s is deprecated; build a MatchPlan instead: "
                "plan = build_plan(MatchSpec(algo=...), n_sub, n_upd, d); "
                "see docs/API.md")


def _legacy_spec(algo: str, max_pairs: int, kw: dict) -> MatchSpec:
    if algo not in ALGOS:
        raise ValueError(f"algo must be one of {ALGOS}")
    fields = {}
    for key in ("tile", "ncells", "p", "swap"):
        if key in kw:
            fields[key] = kw.pop(key)
    if kw:
        raise TypeError(f"unknown match kwargs: {sorted(kw)}")
    return MatchSpec(algo=algo, backend="xla", capacity="fixed",
                     max_pairs=max_pairs, **fields)


def match_count(S: Regions, U: Regions, algo: str = "sbm", *,
                max_pairs: int | None = None, **kw) -> int:
    """Deprecated: use ``build_plan(spec, ...).count(S, U)``.

    Total number of overlapping (subscription, update) pairs — always
    exact; ``max_pairs`` never affects the result (kept for signature
    compatibility).
    """
    warnings.warn(_DEPRECATION % "match_count", DeprecationWarning,
                  stacklevel=2)
    spec = _legacy_spec(algo, max_pairs or 1, dict(kw))
    return build_plan(spec, S.n, U.n, S.d).count(S, U)


def match_pairs(S: Regions, U: Regions, max_pairs: int,
                algo: str = "sbm", **kw):
    """Deprecated: use ``build_plan(spec, ...).pairs(S, U)``.

    Enumerate overlapping pairs, each exactly once, into a −1-padded
    ``(max_pairs, 2)`` buffer; ``count`` is the exact K (truncation is
    the caller's overflow decision).  Identical semantics to the
    engine's ``capacity="fixed"`` policy.
    """
    warnings.warn(_DEPRECATION % "match_pairs", DeprecationWarning,
                  stacklevel=2)
    spec = _legacy_spec(algo, max_pairs, dict(kw))
    return build_plan(spec, S.n, U.n, S.d).pairs(S, U)


# ---------------------------------------------------------------------------
# block masks (DDM as a planner for block-sparse attention; sparse/)
# ---------------------------------------------------------------------------

@jax.jit
def block_mask(q_lo: Array, q_hi: Array, kv_lo: Array, kv_hi: Array
               ) -> Array:
    """(nq, nkv) overlap mask between 1-D query/kv interval batches."""
    return jnp.logical_and(q_lo[:, None] < kv_hi[None, :],
                           kv_lo[None, :] < q_hi[:, None])


def pairs_to_set(pairs: Array, m: int, n: int | None = None, *,
                 context: object = None) -> set[int]:
    """Host-side helper: −1-padded (k, 2) pair buffer → ``{s*m + u}`` set.

    Validates every non-pad pair against the region-set sizes: update
    indices must lie in ``[0, m)`` and, when ``n`` is given,
    subscription indices in ``[0, n)`` — out-of-range indices used to
    alias silently under the ``s*m + u`` encoding.  On failure the error
    names the offending slots, their (s, u) values, and the valid
    ranges; pass ``context=plan`` (anything with a useful ``repr``) to
    have it appear in the message.

    A lazy CSR view (``kernels.ops.CSRPairs``) is consumed window by
    window — validation and set assembly run per chunk, so the dense
    ``(cap, 2)`` buffer is never materialized even for quadratic-K
    caps (duck-typed on ``windows()`` to keep core free of a kernels
    import).
    """
    from .engine import describe_pair_range_errors

    out: set[int] = set()
    if hasattr(pairs, "windows") and hasattr(pairs, "decode"):
        for w0, arr in pairs.windows():
            problems = describe_pair_range_errors(arr, m, n)
            if problems:
                ctx = (f"; context={context!r}" if context is not None
                       else "")
                raise ValueError(
                    "pair buffer index-range failure (CSR window at "
                    f"slot {w0}): " + "; ".join(problems) + ctx)
            arr = arr[arr[:, 0] >= 0]
            out.update((arr[:, 0].astype(np.int64) * m
                        + arr[:, 1]).tolist())
        return out

    arr = np.asarray(pairs)
    problems = describe_pair_range_errors(arr, m, n)
    if problems:
        ctx = f"; context={context!r}" if context is not None else ""
        raise ValueError("pair buffer index-range failure: "
                         + "; ".join(problems) + ctx)
    arr = arr[arr[:, 0] >= 0]
    return set((arr[:, 0].astype(np.int64) * m + arr[:, 1]).tolist())
