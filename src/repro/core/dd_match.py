"""Public DDM matching API — d-dimensional region matching (paper §2).

The d>1 case reduces to d=1: two d-rectangles overlap iff their
projections overlap on *every* dimension.  The paper combines per-
dimension 1-D results with hash-set intersection; the TPU-idiomatic
equivalent here is **match-then-verify**: enumerate candidate pairs on one
dimension with the chosen 1-D algorithm (static-capacity buffers), then
filter the candidates on the remaining dimensions with a vectorized
gather + compare.  This does the same work as set intersection but with
regular memory access (DESIGN.md §2).

Counting in d>1 requires pair identity, so it shares the enumeration path
(except BFM, whose tiled mask already tests all dimensions at once).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import brute, grid, itm, sbm
from .regions import Regions

Array = jax.Array

ALGOS = ("bfm", "gbm", "sbm", "sbm_chunked", "sbm_binary", "itm")


def _project(R: Regions, dim: int) -> Regions:
    return Regions(R.lo[:, dim:dim + 1], R.hi[:, dim:dim + 1])


# ---------------------------------------------------------------------------
# counting
# ---------------------------------------------------------------------------

def match_count(S: Regions, U: Regions, algo: str = "sbm", *,
                max_pairs: int | None = None, **kw) -> int:
    """Total number of overlapping (subscription, update) pairs.

    Always exact.  For d > 1 the dim-0 candidate buffer is sized from the
    *exact* dim-0 pair count (binary-search SBM per-sub counts), so there
    is no overflow path; a caller-supplied ``max_pairs`` only ever grows
    the buffer.
    """
    if algo not in ALGOS:
        raise ValueError(f"algo must be one of {ALGOS}")
    if S.n == 0 or U.n == 0:
        return 0
    if S.d == 1:
        if algo == "bfm":
            return brute.bfm_count(S, U, **kw)
        if algo == "gbm":
            return grid.gbm_count(S, U, **kw)
        if algo == "sbm":
            return sbm.sbm_count_sweep(S, U)
        if algo == "sbm_chunked":
            return sbm.sbm_count_chunked(S, U, **kw)
        if algo == "sbm_binary":
            return sbm.sbm_count_binary(S, U)
        if algo == "itm":
            return itm.itm_count(S, U, **kw)
    if algo == "bfm":
        return brute.bfm_count(S, U, **kw)  # mask tests all dims at once
    # match dim 0 (exact, exactly-sized candidate buffer inside
    # match_pairs), verify the rest; the count is exact regardless of the
    # output buffer size.
    pairs, count = match_pairs(S, U, max_pairs=max_pairs or 1,
                               algo=algo, **kw)
    return int(count)


def _candidate_bound(S: Regions, U: Regions) -> int:
    """Exact dim-0 candidate count (binary-search SBM per-sub counts)."""
    c = sbm.sbm_count_per_sub(_project(S, 0), _project(U, 0))
    return max(int(np.sum(np.asarray(c), dtype=np.int64)), 1)


# ---------------------------------------------------------------------------
# pair enumeration
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_pairs",))
def _verify_dims(S: Regions, U: Regions, cand: Array, max_pairs: int):
    """Filter dim-0 candidate pairs on dimensions 1..d-1, recompact."""
    s_idx, u_idx = cand[:, 0], cand[:, 1]
    valid = s_idx >= 0
    si = jnp.maximum(s_idx, 0)
    ui = jnp.maximum(u_idx, 0)
    ok = jnp.all(
        jnp.logical_and(S.lo[si, 1:] < U.hi[ui, 1:],
                        U.lo[ui, 1:] < S.hi[si, 1:]), axis=-1)
    ok = ok & valid
    count = jnp.sum(ok, dtype=jnp.int32)
    keep = jnp.nonzero(ok, size=max_pairs, fill_value=-1)[0]
    out = jnp.where(keep[:, None] >= 0, cand[jnp.maximum(keep, 0)], -1)
    return out, count


def match_pairs(S: Regions, U: Regions, max_pairs: int,
                algo: str = "sbm", **kw):
    """Enumerate overlapping pairs, each exactly once, −1-padded buffer.

    Returns ``(pairs int32 (max_pairs, 2), count)``.  ``count`` is the
    exact number of overlaps (int64-safe); if it exceeds ``max_pairs``
    the buffer is truncated (caller decides whether that is an overflow).
    Empty S or U yields a well-formed all-−1 buffer with count 0 for
    every algorithm.
    """
    if algo not in ALGOS:
        raise ValueError(f"algo must be one of {ALGOS}")
    if S.n == 0 or U.n == 0:
        return jnp.full((max_pairs, 2), -1, jnp.int32), 0
    if algo == "bfm" or (S.d > 1 and algo == "gbm"):
        return brute.bfm_pairs(S, U, max_pairs)
    S0, U0 = _project(S, 0), _project(U, 0)
    # d > 1: the dim-0 candidate buffer must hold EVERY dim-0 overlap or
    # verification would silently drop true pairs — size it from the
    # exact dim-0 count, independent of the caller's output cap.
    cand_cap = max_pairs if S.d == 1 else _candidate_bound(S, U)
    if algo in ("sbm", "sbm_chunked", "sbm_binary"):
        cand, ccount = sbm.sbm_pairs(S0, U0, cand_cap, **kw)
    elif algo == "itm":
        T = itm.build_tree(S0)
        counts = itm.itm_query_counts(T, U0.lo[:, 0], U0.hi[:, 0])
        cap = max(int(np.max(np.asarray(counts), initial=0)), 1)
        ids, _ = itm.itm_query_pairs(T, U0.lo[:, 0], U0.hi[:, 0], cap)
        nq = ids.shape[0]
        u_idx = jnp.broadcast_to(
            jnp.arange(nq, dtype=jnp.int32)[:, None], ids.shape)
        flat_ok = (ids >= 0).ravel()
        sel = jnp.nonzero(flat_ok, size=cand_cap, fill_value=-1)[0]
        s_sel = jnp.where(sel >= 0, ids.ravel()[jnp.maximum(sel, 0)], -1)
        u_sel = jnp.where(sel >= 0, u_idx.ravel()[jnp.maximum(sel, 0)], -1)
        cand = jnp.stack([s_sel, u_sel], axis=1)
        ccount = int(np.sum(np.asarray(counts), dtype=np.int64))
    elif algo == "gbm":
        return brute.bfm_pairs(S, U, max_pairs)
    else:
        raise ValueError(f"algo must be one of {ALGOS}")
    if S.d == 1:
        return cand, ccount
    return _verify_dims(S, U, cand, max_pairs)


# ---------------------------------------------------------------------------
# block masks (DDM as a planner for block-sparse attention; sparse/)
# ---------------------------------------------------------------------------

@jax.jit
def block_mask(q_lo: Array, q_hi: Array, kv_lo: Array, kv_hi: Array
               ) -> Array:
    """(nq, nkv) overlap mask between 1-D query/kv interval batches."""
    return jnp.logical_and(q_lo[:, None] < kv_hi[None, :],
                           kv_lo[None, :] < q_hi[:, None])


def pairs_to_set(pairs: Array, m: int) -> set[int]:
    """Host-side helper: −1-padded (k,2) pair buffer → {s*m+u} set."""
    arr = np.asarray(pairs)
    arr = arr[arr[:, 0] >= 0]
    return set((arr[:, 0].astype(np.int64) * m + arr[:, 1]).tolist())
