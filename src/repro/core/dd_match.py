"""DDM matching helpers shared across the engine's consumers.

The d-dimensional matching implementation lives in
``repro.core.engine`` behind the plan/compile/execute API::

    spec = MatchSpec(algo="sbm", backend="xla", capacity="fixed",
                     max_pairs=cap)
    plan = build_plan(spec, n_sub=S.n, n_upd=U.n, d=S.d)
    res, k = plan.pairs(S, U)

The pre-engine entry points (``match_count`` / ``match_pairs``) went
through a deprecation cycle and are now removed — ``docs/API.md`` keeps
the migration table.  What remains here are plain helpers:
``block_mask`` (the sparse-attention planner primitive) and
``pairs_to_set`` (validated host-side set assembly over any
``core.pairs.PairsResult``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .pairs import PairsResult

Array = jax.Array


# ---------------------------------------------------------------------------
# block masks (DDM as a planner for block-sparse attention; sparse/)
# ---------------------------------------------------------------------------

@jax.jit
def block_mask(q_lo: Array, q_hi: Array, kv_lo: Array, kv_hi: Array
               ) -> Array:
    """(nq, nkv) overlap mask between 1-D query/kv interval batches."""
    return jnp.logical_and(q_lo[:, None] < kv_hi[None, :],
                           kv_lo[None, :] < q_hi[:, None])


def pairs_to_set(pairs, m: int, n: int | None = None, *,
                 context: object = None) -> set[int]:
    """Host-side helper: −1-padded (k, 2) pair buffer → ``{s*m + u}`` set.

    Validates every non-pad pair against the region-set sizes: update
    indices must lie in ``[0, m)`` and, when ``n`` is given,
    subscription indices in ``[0, n)`` — out-of-range indices used to
    alias silently under the ``s*m + u`` encoding.  On failure the error
    names the offending slots, their (s, u) values, and the valid
    ranges; pass ``context=plan`` (anything with a useful ``repr``) to
    have it appear in the message.

    Any ``core.pairs.PairsResult`` — the ``DensePairs`` wrapper or a
    lazy CSR view — is consumed window by window: validation and set
    assembly run per chunk, so the dense ``(cap, 2)`` buffer is never
    materialized even for quadratic-K caps.  Raw arrays still work via
    ``np.asarray`` for callers holding pre-contract buffers.
    """
    from .engine import describe_pair_range_errors

    if isinstance(pairs, PairsResult):
        out: set[int] = set()
        for w0, arr in pairs.windows():
            problems = describe_pair_range_errors(arr, m, n)
            if problems:
                ctx = (f"; context={context!r}" if context is not None
                       else "")
                raise ValueError(
                    "pair buffer index-range failure (window at "
                    f"slot {w0}): " + "; ".join(problems) + ctx)
            arr = arr[arr[:, 0] >= 0]
            out.update((arr[:, 0].astype(np.int64) * m
                        + arr[:, 1]).tolist())
        return out

    arr = np.asarray(pairs)
    problems = describe_pair_range_errors(arr, m, n)
    if problems:
        ctx = f"; context={context!r}" if context is not None else ""
        raise ValueError("pair buffer index-range failure: "
                         + "; ".join(problems) + ctx)
    arr = arr[arr[:, 0] >= 0]
    return set((arr[:, 0].astype(np.int64) * m + arr[:, 1]).tolist())
