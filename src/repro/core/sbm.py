"""Sort-Based Matching — paper Algorithms 4/6/7, as data-parallel JAX.

The paper's contribution is the observation that SBM's sweep — a loop with
a carried dependence through the active-sets ``SubSet``/``UpdSet`` — is a
*prefix computation* over the set-algebra monoid, hence parallelizable
with a scan (Alg. 7: per-segment local deltas ``Sadd/Sdel/Uadd/Udel``, an
exclusive scan combining them, then independent local sweeps).

TPU adaptation (DESIGN.md §2): for *counting* (what the paper's own
evaluation measures) the monoid carrier collapses from sets to integers —
``|SubSet|``/``|UpdSet|`` — a commutative group, so the scan is a plain
``cumsum`` over the lex-sorted endpoint stream.  Three equivalent
implementations are provided, from most- to least-faithful to Alg. 6/7
structure; all are bit-identical and cross-checked in tests:

* ``sbm_count_chunked``  — explicit P-segment version: local scans +
  exclusive combine + local sweeps (Alg. 6/7 with P static).
* ``sbm_count_sweep``    — the P→2N limit: one lex-sort + one cumsum.
* ``sbm_count_binary``   — Li et al. [38] binary-search variant (two
  sorted arrays + searchsorted), which also yields *per-region* counts
  used by the dynamic DDM service.

Endpoint ordering: half-open intervals require upper endpoints to be
processed *before* lower endpoints at equal coordinate (so ``[a,b)`` and
``[b,c)`` never match); ``jnp.lexsort`` with the hi/lo flag as secondary
key encodes exactly that.

Precondition: regions are non-empty (``lo < hi``), as in the paper
(region length l > 0).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .regions import Regions

Array = jax.Array


# ---------------------------------------------------------------------------
# endpoint stream construction
# ---------------------------------------------------------------------------

def _endpoint_stream(s_lo, s_hi, u_lo, u_hi):
    """Build the lex-sorted endpoint stream for one dimension.

    Returns (is_lo, is_upd) int32 arrays in sweep order (2N,).
    Sort key: (value asc, hi-before-lo).  is_lo=0 sorts first at ties.
    """
    v = jnp.concatenate([s_lo, s_hi, u_lo, u_hi])
    n, m = s_lo.shape[0], u_lo.shape[0]
    is_lo = jnp.concatenate([
        jnp.ones(n, jnp.int32), jnp.zeros(n, jnp.int32),
        jnp.ones(m, jnp.int32), jnp.zeros(m, jnp.int32),
    ])
    is_upd = jnp.concatenate([
        jnp.zeros(n, jnp.int32), jnp.zeros(n, jnp.int32),
        jnp.ones(m, jnp.int32), jnp.ones(m, jnp.int32),
    ])
    order = jnp.lexsort((is_lo, v))  # primary v, secondary is_lo (hi first)
    return is_lo[order], is_upd[order]


@jax.jit
def _sweep_contribs(s_lo, s_hi, u_lo, u_hi) -> Array:
    """Per-endpoint report counts of the SBM sweep (int32, (2N,)).

    At each *upper* endpoint the sweep reports the region against every
    active region of the opposite kind (Alg. 4 lines 12/18); with counting
    carriers that is the current active count of the opposite kind.
    """
    is_lo, is_upd = _endpoint_stream(s_lo, s_hi, u_lo, u_hi)
    is_hi = 1 - is_lo
    is_sub = 1 - is_upd
    # active counts AFTER processing endpoint i (inclusive cumsum):
    upd_active = jnp.cumsum(is_upd * is_lo) - jnp.cumsum(is_upd * is_hi)
    sub_active = jnp.cumsum(is_sub * is_lo) - jnp.cumsum(is_sub * is_hi)
    # a hi endpoint's own flags contribute 0 to the opposite kind's counts,
    # so the inclusive cumsum is exactly "UpdSet/SubSet at report time".
    contrib = is_hi * (is_sub * upd_active + is_upd * sub_active)
    return contrib.astype(jnp.int32)


def sbm_count_sweep(S: Regions, U: Regions) -> int:
    """Total K by the sweep-as-prefix-sum formulation (d-dim: see dd_match).

    d must be 1 here; multi-d composition needs pair identities and lives
    in ``dd_match.match_count``.
    """
    assert S.d == 1, "sbm_count_sweep is the 1-D primitive (see dd_match)"
    c = _sweep_contribs(S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0])
    return int(np.sum(np.asarray(c), dtype=np.int64))


# ---------------------------------------------------------------------------
# Alg. 6/7 structure made explicit: P segments, local scans, prefix combine
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("p",))
def _chunked_contribs(s_lo, s_hi, u_lo, u_hi, p: int) -> Array:
    """Counting SBM with the paper's explicit 3-step structure (Alg. 7).

    Step ①: each of the ``p`` segments scans locally, producing its delta
            (#lo − #hi) per kind — the counting image of Sadd/Sdel/Uadd/Udel.
    Step ②: exclusive scan over segment deltas = SubSet[p]/UpdSet[p] sizes.
    Step ③: independent local sweeps seeded with those initial counts.

    Identical output to ``_sweep_contribs``; exists to (a) document the
    mapping paper→TPU, (b) seed the multi-device version in
    ``core.distributed`` which runs step ② as a mesh collective.
    """
    is_lo, is_upd = _endpoint_stream(s_lo, s_hi, u_lo, u_hi)
    tot = is_lo.shape[0]
    pad = (-tot) % p
    # sentinel endpoints: sub-lo at the stream end contribute nothing
    is_lo = jnp.pad(is_lo, (0, pad), constant_values=1)
    is_upd = jnp.pad(is_upd, (0, pad), constant_values=0)
    seg = is_lo.shape[0] // p
    is_lo = is_lo.reshape(p, seg)
    is_upd = is_upd.reshape(p, seg)
    is_hi, is_sub = 1 - is_lo, 1 - is_upd

    d_upd = is_upd * (is_lo - is_hi)          # per-endpoint active delta
    d_sub = is_sub * (is_lo - is_hi)
    # step ① local inclusive scans
    upd_local = jnp.cumsum(d_upd, axis=1)
    sub_local = jnp.cumsum(d_sub, axis=1)
    # step ② exclusive combine across segments (the "master" prefix)
    upd_carry = jnp.concatenate([jnp.zeros((1,), d_upd.dtype),
                                 jnp.cumsum(upd_local[:-1, -1])])
    sub_carry = jnp.concatenate([jnp.zeros((1,), d_sub.dtype),
                                 jnp.cumsum(sub_local[:-1, -1])])
    # step ③ seeded local sweeps
    upd_active = upd_local + upd_carry[:, None]
    sub_active = sub_local + sub_carry[:, None]
    contrib = is_hi * (is_sub * upd_active + is_upd * sub_active)
    return contrib.reshape(-1)[:tot].astype(jnp.int32)


def sbm_count_chunked(S: Regions, U: Regions, p: int = 8) -> int:
    assert S.d == 1
    c = _chunked_contribs(S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0], p)
    return int(np.sum(np.asarray(c), dtype=np.int64))


# ---------------------------------------------------------------------------
# Binary-search variant (Li et al. [38]) — per-region counts
# ---------------------------------------------------------------------------

@jax.jit
def sbm_count_per_sub(S: Regions, U: Regions) -> Array:
    """K_s for every subscription region (1-D regions), int32 (n,).

    K_s = |{u : u.lo < s.hi}| − |{u : u.hi ≤ s.lo}|   (non-empty intervals)
    — two sorted arrays + two searchsorted calls; O((n+m) lg m) and fully
    parallel over s, no sweep at all.
    """
    s_lo, s_hi = S.lo[:, 0], S.hi[:, 0]
    u_lo = jnp.sort(U.lo[:, 0])
    u_hi = jnp.sort(U.hi[:, 0])
    below = jnp.searchsorted(u_lo, s_hi, side="left")
    gone = jnp.searchsorted(u_hi, s_lo, side="right")
    return (below - gone).astype(jnp.int32)


def sbm_count_binary(S: Regions, U: Regions) -> int:
    c = sbm_count_per_sub(S, U)
    return int(np.sum(np.asarray(c), dtype=np.int64))


# ---------------------------------------------------------------------------
# Pair enumeration — exact two-pass count-then-emit (no window measurement)
# ---------------------------------------------------------------------------
#
# Every overlap (s, u) of non-empty half-open intervals falls into exactly
# one of two classes:
#
#   A: u.lo ∈ [s.lo, s.hi)  — then u.hi > u.lo ≥ s.lo, so overlap holds.
#      In lo-sorted U this is the contiguous index range [aA_s, rA_s).
#   B: u.lo < s.lo < u.hi   — i.e. s.lo stabs u from inside.  Flipping
#      roles, these are the s whose lo lies in (u.lo, u.hi): the
#      contiguous range [bB_u, cB_u) of lo-sorted S.
#
# Both classes are searchsorted ranges, so pass 1 yields exact per-emitter
# counts, an exclusive scan yields output offsets, and pass 2 emits every
# pair into its slot fully in parallel — no data-dependent window, no
# host-side l_max measurement, no overflow on long-region workloads.
# (The scan saturates at max_pairs so slot arithmetic stays in int32 even
# when the true K exceeds the buffer; the exact K is summed host-side in
# int64 from the unclipped per-emitter counts.)

def _twopass_phase1(s_lo, s_hi, u_lo, u_hi, max_pairs: int):
    """Pass 1 of count-then-emit: per-emitter counts and slot offsets.

    Returns ``(perm_s, perm_u, starts, counts, offs, cnt_a, cnt_b)``:
    ``starts`` is the concatenated per-emitter input offsets (aA for the n
    class-A emitters, bB for the m class-B emitters), ``counts`` the
    concatenated unclipped per-emitter pair counts, ``offs`` the
    (n+m+1,) exclusive-scan output offsets saturated at ``max_pairs``.
    Shared by the XLA pass-2 (``_twopass_emit``) and the fused Pallas
    emit kernel (``kernels.ops.twopass_pairs_pallas``).
    """
    perm_u = jnp.argsort(u_lo).astype(jnp.int32)
    perm_s = jnp.argsort(s_lo).astype(jnp.int32)
    u_lo_sorted = u_lo[perm_u]
    s_lo_sorted = s_lo[perm_s]

    # exact per-emitter counts (A: one emitter per s; B: per u)
    aA = jnp.searchsorted(u_lo_sorted, s_lo, side="left").astype(jnp.int32)
    rA = jnp.searchsorted(u_lo_sorted, s_hi, side="left").astype(jnp.int32)
    bB = jnp.searchsorted(s_lo_sorted, u_lo, side="right").astype(jnp.int32)
    cB = jnp.searchsorted(s_lo_sorted, u_hi, side="left").astype(jnp.int32)
    # the maximum(·, 0) guards the offsets scan against degenerate
    # (empty, lo == hi) intervals, which violate the module precondition
    # but must not corrupt emission for the well-formed regions
    cnt_a = jnp.maximum(rA - aA, 0)                        # (n,)
    cnt_b = jnp.maximum(cB - bB, 0)                        # (m,)

    # exclusive-scan offsets, saturating at max_pairs: offsets below the
    # buffer limit stay exact; emitters wholly past it land on the limit
    # and are never selected by the slot lookup.
    starts = jnp.concatenate([aA, bB])
    counts = jnp.concatenate([cnt_a, cnt_b])
    lim = jnp.int32(max_pairs)
    incl = jax.lax.associative_scan(
        lambda a, b: jnp.minimum(a + b, lim), counts)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), incl])
    return perm_s, perm_u, starts, counts, offs, cnt_a, cnt_b


@partial(jax.jit, static_argnames=("max_pairs",))
def _twopass_emit(s_lo, s_hi, u_lo, u_hi, max_pairs: int):
    n, m = s_lo.shape[0], u_lo.shape[0]
    perm_s, perm_u, starts, counts, offs, cnt_a, cnt_b = _twopass_phase1(
        s_lo, s_hi, u_lo, u_hi, max_pairs)
    aA, bB = starts[:n], starts[n:]

    # pass 2: one thread per output slot
    t = jnp.arange(max_pairs, dtype=jnp.int32)
    e = jnp.searchsorted(offs, t, side="right").astype(jnp.int32) - 1
    e = jnp.minimum(e, n + m - 1)
    j = t - offs[e]
    valid = (j >= 0) & (j < counts[e])
    is_a = e < n
    e_a = jnp.minimum(e, n - 1)
    e_b = jnp.clip(e - n, 0, m - 1)
    u_from_a = perm_u[jnp.clip(aA[e_a] + j, 0, m - 1)]
    s_from_b = perm_s[jnp.clip(bB[e_b] + j, 0, n - 1)]
    s_idx = jnp.where(valid, jnp.where(is_a, e_a, s_from_b), -1)
    u_idx = jnp.where(valid, jnp.where(is_a, u_from_a, e_b), -1)
    pairs = jnp.stack([s_idx, u_idx], axis=1).astype(jnp.int32)
    return pairs, cnt_a, cnt_b


def sbm_pairs(S: Regions, U: Regions, max_pairs: int):
    """Enumerate 1-D overlaps exactly via two-pass count-then-emit.

    Returns ``(pairs, count)``: ``pairs`` is int32 (max_pairs, 2) padded
    with −1; ``count`` is the exact total K as a python int (int64-safe),
    cross-checkable against ``sbm_count_per_sub(S, U).sum()``.  If
    ``count > max_pairs`` the buffer holds the first ``max_pairs`` pairs
    in emission order (explicit truncation — the caller decides whether
    that is an overflow).  Empty S or U returns a well-formed all-−1
    buffer with count 0.
    """
    assert S.d == 1
    if S.n == 0 or U.n == 0:
        return jnp.full((max_pairs, 2), -1, jnp.int32), 0
    pairs, cnt_a, cnt_b = _twopass_emit(
        S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0], max_pairs)
    count = int(np.sum(np.asarray(cnt_a), dtype=np.int64)
                + np.sum(np.asarray(cnt_b), dtype=np.int64))
    return pairs, count
