"""Sort-Based Matching — paper Algorithms 4/6/7, as data-parallel JAX.

The paper's contribution is the observation that SBM's sweep — a loop with
a carried dependence through the active-sets ``SubSet``/``UpdSet`` — is a
*prefix computation* over the set-algebra monoid, hence parallelizable
with a scan (Alg. 7: per-segment local deltas ``Sadd/Sdel/Uadd/Udel``, an
exclusive scan combining them, then independent local sweeps).

TPU adaptation (DESIGN.md §2): for *counting* (what the paper's own
evaluation measures) the monoid carrier collapses from sets to integers —
``|SubSet|``/``|UpdSet|`` — a commutative group, so the scan is a plain
``cumsum`` over the lex-sorted endpoint stream.  Three equivalent
implementations are provided, from most- to least-faithful to Alg. 6/7
structure; all are bit-identical and cross-checked in tests:

* ``sbm_count_chunked``  — explicit P-segment version: local scans +
  exclusive combine + local sweeps (Alg. 6/7 with P static).
* ``sbm_count_sweep``    — the P→2N limit: one lex-sort + one cumsum.
* ``sbm_count_binary``   — Li et al. [38] binary-search variant (two
  sorted arrays + searchsorted), which also yields *per-region* counts
  used by the dynamic DDM service.

Endpoint ordering: half-open intervals require upper endpoints to be
processed *before* lower endpoints at equal coordinate (so ``[a,b)`` and
``[b,c)`` never match); ``jnp.lexsort`` with the hi/lo flag as secondary
key encodes exactly that.

Precondition: regions are non-empty (``lo < hi``), as in the paper
(region length l > 0).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .regions import Regions

Array = jax.Array


# ---------------------------------------------------------------------------
# endpoint stream construction
# ---------------------------------------------------------------------------

def _endpoint_stream(s_lo, s_hi, u_lo, u_hi):
    """Build the lex-sorted endpoint stream for one dimension.

    Returns (is_lo, is_upd) int32 arrays in sweep order (2N,).
    Sort key: (value asc, hi-before-lo).  is_lo=0 sorts first at ties.
    """
    v = jnp.concatenate([s_lo, s_hi, u_lo, u_hi])
    n, m = s_lo.shape[0], u_lo.shape[0]
    is_lo = jnp.concatenate([
        jnp.ones(n, jnp.int32), jnp.zeros(n, jnp.int32),
        jnp.ones(m, jnp.int32), jnp.zeros(m, jnp.int32),
    ])
    is_upd = jnp.concatenate([
        jnp.zeros(n, jnp.int32), jnp.zeros(n, jnp.int32),
        jnp.ones(m, jnp.int32), jnp.ones(m, jnp.int32),
    ])
    order = jnp.lexsort((is_lo, v))  # primary v, secondary is_lo (hi first)
    return is_lo[order], is_upd[order]


@jax.jit
def _sweep_contribs(s_lo, s_hi, u_lo, u_hi) -> Array:
    """Per-endpoint report counts of the SBM sweep (int32, (2N,)).

    At each *upper* endpoint the sweep reports the region against every
    active region of the opposite kind (Alg. 4 lines 12/18); with counting
    carriers that is the current active count of the opposite kind.
    """
    is_lo, is_upd = _endpoint_stream(s_lo, s_hi, u_lo, u_hi)
    is_hi = 1 - is_lo
    is_sub = 1 - is_upd
    # active counts AFTER processing endpoint i (inclusive cumsum):
    upd_active = jnp.cumsum(is_upd * is_lo) - jnp.cumsum(is_upd * is_hi)
    sub_active = jnp.cumsum(is_sub * is_lo) - jnp.cumsum(is_sub * is_hi)
    # a hi endpoint's own flags contribute 0 to the opposite kind's counts,
    # so the inclusive cumsum is exactly "UpdSet/SubSet at report time".
    contrib = is_hi * (is_sub * upd_active + is_upd * sub_active)
    return contrib.astype(jnp.int32)


def sbm_count_sweep(S: Regions, U: Regions) -> int:
    """Total K by the sweep-as-prefix-sum formulation (d-dim: see dd_match).

    d must be 1 here; multi-d composition needs pair identities and lives
    in the engine's match-then-verify path (``engine.MatchPlan``).
    """
    assert S.d == 1, "sbm_count_sweep is the 1-D primitive (see dd_match)"
    c = _sweep_contribs(S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0])
    return int(np.sum(np.asarray(c), dtype=np.int64))


# ---------------------------------------------------------------------------
# Alg. 6/7 structure made explicit: P segments, local scans, prefix combine
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("p",))
def _chunked_contribs(s_lo, s_hi, u_lo, u_hi, p: int) -> Array:
    """Counting SBM with the paper's explicit 3-step structure (Alg. 7).

    Step ①: each of the ``p`` segments scans locally, producing its delta
            (#lo − #hi) per kind — the counting image of Sadd/Sdel/Uadd/Udel.
    Step ②: exclusive scan over segment deltas = SubSet[p]/UpdSet[p] sizes.
    Step ③: independent local sweeps seeded with those initial counts.

    Identical output to ``_sweep_contribs``; exists to (a) document the
    mapping paper→TPU, (b) seed the multi-device version in
    ``core.distributed`` which runs step ② as a mesh collective.
    """
    is_lo, is_upd = _endpoint_stream(s_lo, s_hi, u_lo, u_hi)
    tot = is_lo.shape[0]
    pad = (-tot) % p
    # sentinel endpoints: sub-lo at the stream end contribute nothing
    is_lo = jnp.pad(is_lo, (0, pad), constant_values=1)
    is_upd = jnp.pad(is_upd, (0, pad), constant_values=0)
    seg = is_lo.shape[0] // p
    is_lo = is_lo.reshape(p, seg)
    is_upd = is_upd.reshape(p, seg)
    is_hi, is_sub = 1 - is_lo, 1 - is_upd

    d_upd = is_upd * (is_lo - is_hi)          # per-endpoint active delta
    d_sub = is_sub * (is_lo - is_hi)
    # step ① local inclusive scans
    upd_local = jnp.cumsum(d_upd, axis=1)
    sub_local = jnp.cumsum(d_sub, axis=1)
    # step ② exclusive combine across segments (the "master" prefix)
    upd_carry = jnp.concatenate([jnp.zeros((1,), d_upd.dtype),
                                 jnp.cumsum(upd_local[:-1, -1])])
    sub_carry = jnp.concatenate([jnp.zeros((1,), d_sub.dtype),
                                 jnp.cumsum(sub_local[:-1, -1])])
    # step ③ seeded local sweeps
    upd_active = upd_local + upd_carry[:, None]
    sub_active = sub_local + sub_carry[:, None]
    contrib = is_hi * (is_sub * upd_active + is_upd * sub_active)
    return contrib.reshape(-1)[:tot].astype(jnp.int32)


def sbm_count_chunked(S: Regions, U: Regions, p: int = 8) -> int:
    assert S.d == 1
    c = _chunked_contribs(S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0], p)
    return int(np.sum(np.asarray(c), dtype=np.int64))


# ---------------------------------------------------------------------------
# Binary-search variant (Li et al. [38]) — per-region counts
# ---------------------------------------------------------------------------

@jax.jit
def sbm_count_per_sub(S: Regions, U: Regions) -> Array:
    """K_s for every subscription region (1-D regions), int32 (n,).

    K_s = |{u : u.lo < s.hi}| − |{u : u.hi ≤ s.lo}|   (non-empty intervals)
    — two sorted arrays + two searchsorted calls; O((n+m) lg m) and fully
    parallel over s, no sweep at all.
    """
    s_lo, s_hi = S.lo[:, 0], S.hi[:, 0]
    u_lo = jnp.sort(U.lo[:, 0])
    u_hi = jnp.sort(U.hi[:, 0])
    below = jnp.searchsorted(u_lo, s_hi, side="left")
    gone = jnp.searchsorted(u_hi, s_lo, side="right")
    return (below - gone).astype(jnp.int32)


def sbm_count_binary(S: Regions, U: Regions) -> int:
    c = sbm_count_per_sub(S, U)
    return int(np.sum(np.asarray(c), dtype=np.int64))


# ---------------------------------------------------------------------------
# Pair enumeration — exact two-pass count-then-emit (no window measurement)
# ---------------------------------------------------------------------------
#
# Every overlap (s, u) of non-empty half-open intervals falls into exactly
# one of two classes:
#
#   A: u.lo ∈ [s.lo, s.hi)  — then u.hi > u.lo ≥ s.lo, so overlap holds.
#      In lo-sorted U this is the contiguous index range [aA_s, rA_s).
#   B: u.lo < s.lo < u.hi   — i.e. s.lo stabs u from inside.  Flipping
#      roles, these are the s whose lo lies in (u.lo, u.hi): the
#      contiguous range [bB_u, cB_u) of lo-sorted S.
#
# Both classes are searchsorted ranges, so pass 1 yields exact per-emitter
# counts, an exclusive scan yields output offsets, and pass 2 emits every
# pair into its slot fully in parallel — no data-dependent window, no
# host-side l_max measurement, no overflow on long-region workloads.
# (The scan saturates at max_pairs so slot arithmetic stays in int32 even
# when the true K exceeds the buffer; the exact K is summed host-side in
# int64 from the unclipped per-emitter counts.)

def _twopass_phase1(s_lo, s_hi, u_lo, u_hi, max_pairs: int):
    """Pass 1 of count-then-emit: per-emitter counts and slot offsets.

    Returns ``(perm_s, perm_u, starts, counts, offs, cnt_a, cnt_b)``:
    ``starts`` is the concatenated per-emitter input offsets (aA for the n
    class-A emitters, bB for the m class-B emitters), ``counts`` the
    concatenated unclipped per-emitter pair counts, ``offs`` the
    (n+m+1,) exclusive-scan output offsets saturated at ``max_pairs``.
    Shared by the XLA pass-2 (``_twopass_emit``) and the fused Pallas
    emit kernel (``kernels.ops.twopass_pairs_pallas``).
    """
    perm_u = jnp.argsort(u_lo).astype(jnp.int32)
    perm_s = jnp.argsort(s_lo).astype(jnp.int32)
    u_lo_sorted = u_lo[perm_u]
    s_lo_sorted = s_lo[perm_s]

    # exact per-emitter counts (A: one emitter per s; B: per u)
    aA = jnp.searchsorted(u_lo_sorted, s_lo, side="left").astype(jnp.int32)
    rA = jnp.searchsorted(u_lo_sorted, s_hi, side="left").astype(jnp.int32)
    bB = jnp.searchsorted(s_lo_sorted, u_lo, side="right").astype(jnp.int32)
    cB = jnp.searchsorted(s_lo_sorted, u_hi, side="left").astype(jnp.int32)
    # the maximum(·, 0) guards the offsets scan against degenerate
    # (empty, lo == hi) intervals, which violate the module precondition
    # but must not corrupt emission for the well-formed regions
    cnt_a = jnp.maximum(rA - aA, 0)                        # (n,)
    cnt_b = jnp.maximum(cB - bB, 0)                        # (m,)

    # exclusive-scan offsets, saturating at max_pairs: offsets below the
    # buffer limit stay exact; emitters wholly past it land on the limit
    # and are never selected by the slot lookup.
    starts = jnp.concatenate([aA, bB])
    counts = jnp.concatenate([cnt_a, cnt_b])
    lim = jnp.int32(max_pairs)
    incl = jax.lax.associative_scan(
        lambda a, b: jnp.minimum(a + b, lim), counts)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), incl])
    return perm_s, perm_u, starts, counts, offs, cnt_a, cnt_b


@partial(jax.jit, static_argnames=("max_pairs",))
def _twopass_emit(s_lo, s_hi, u_lo, u_hi, max_pairs: int):
    n, m = s_lo.shape[0], u_lo.shape[0]
    perm_s, perm_u, starts, counts, offs, cnt_a, cnt_b = _twopass_phase1(
        s_lo, s_hi, u_lo, u_hi, max_pairs)
    aA, bB = starts[:n], starts[n:]

    # pass 2: one thread per output slot
    t = jnp.arange(max_pairs, dtype=jnp.int32)
    e = jnp.searchsorted(offs, t, side="right").astype(jnp.int32) - 1
    e = jnp.minimum(e, n + m - 1)
    j = t - offs[e]
    valid = (j >= 0) & (j < counts[e])
    is_a = e < n
    e_a = jnp.minimum(e, n - 1)
    e_b = jnp.clip(e - n, 0, m - 1)
    u_from_a = perm_u[jnp.clip(aA[e_a] + j, 0, m - 1)]
    s_from_b = perm_s[jnp.clip(bB[e_b] + j, 0, n - 1)]
    s_idx = jnp.where(valid, jnp.where(is_a, e_a, s_from_b), -1)
    u_idx = jnp.where(valid, jnp.where(is_a, u_from_a, e_b), -1)
    pairs = jnp.stack([s_idx, u_idx], axis=1).astype(jnp.int32)
    return pairs, cnt_a, cnt_b


# ---------------------------------------------------------------------------
# Hybrid grid+SBM (hsbm) — bucketed pass 1, exact per-cell SBM ranges
# ---------------------------------------------------------------------------
#
# Flat two-pass SBM spends most of pass 1 in two global O(n lg n) lo-sorts.
# The hybrid replaces them with (per side) ONE unstable radix-friendly sort
# on sortable-bit int32 keys, then *contiguous gathers* into an
# (ncells, cap) padded per-cell table — cells are monotone in sorted lo, so
# per-cell segments are contiguous runs, no scatter and no second sort.
# Matching stays exact SBM, localized:
#
#   * every overlap class-A/B range argument from the flat two-pass holds
#     within a cell, because with cell width ≥ max region length a pair's
#     max(lo) cell is either the partner's own cell or the one right of it;
#   * each cell's emitter table is [natives | boundary suffix]: the suffix
#     replicates the tail of cell c−1 whose regions can reach into cell c
#     (measured conservatively on the host, see ``grid.hsbm_geometry``).
#     A pair is counted where the *partner* is native — exactly once —
#     so generous suffixes can never double-count.
#
# Per-emitter counts then feed the *same* exclusive-offset → emit machinery
# as the flat path: the saturating scan, the XLA slot loop below, and all
# Pallas emit routes (resident / streaming / CSR) in ``kernels``.

_I32_MAX = jnp.int32(2 ** 31 - 1)


def _sortable_bits(x):
    """Monotone float32 → int32 bijection (IEEE-754 total order trick)."""
    b = x.view(jnp.int32)
    return jnp.where(b < 0, jnp.int32(-2147483648) - b, b)


def _hsbm_side_tables(lo, hi, lb, width, ncells: int, cap: int, suf: int):
    """Bucket one side into per-cell sorted tables.

    Returns ``(nat_bits, emit_bits, emit_ids)``: ``nat_bits`` is the
    (ncells, cap) sortable-bits lo table of cell natives (pads sort to the
    row end as INT32_MAX); ``emit_bits``/``emit_ids`` append the ``suf``
    boundary-suffix columns replicated from the tail of the previous cell
    (ids are original region indices, −1 pads).
    """
    n = lo.shape[0]
    key, perm = jax.lax.sort(
        (_sortable_bits(lo), jnp.arange(n, dtype=jnp.int32)),
        num_keys=1, is_stable=False)
    lo_sorted = jnp.take(lo, perm)
    cells = jnp.clip(jnp.floor((lo_sorted - lb) / width).astype(jnp.int32),
                     0, ncells - 1)
    # cells is monotone in sorted lo ⇒ per-cell runs are contiguous
    starts = jnp.searchsorted(cells, jnp.arange(ncells, dtype=jnp.int32),
                              side="left").astype(jnp.int32)
    occ = jnp.append(starts[1:], jnp.int32(n)) - starts
    j = jnp.arange(cap, dtype=jnp.int32)[None, :]
    idx = starts[:, None] + j
    nat_valid = j < occ[:, None]
    gi = jnp.clip(idx, 0, n - 1)
    nat_bits = jnp.where(nat_valid, jnp.take(key, gi), _I32_MAX)
    nat_ids = jnp.where(nat_valid, jnp.take(perm, gi), -1)
    # boundary suffix: last `suf` natives of cell c−1 (cell 0 has none)
    k = jnp.arange(suf, dtype=jnp.int32)[None, :]
    pocc = jnp.roll(occ, 1).at[0].set(0)
    pstart = jnp.roll(starts, 1).at[0].set(0)
    sidx = pstart[:, None] + pocc[:, None] - suf + k
    s_exists = ((pocc[:, None] - suf + k >= 0)
                & (jnp.arange(ncells)[:, None] > 0))
    sgi = jnp.clip(sidx, 0, n - 1)
    sp_bits = jnp.where(s_exists, jnp.take(key, sgi), _I32_MAX)
    sp_ids = jnp.where(s_exists, jnp.take(perm, sgi), -1)
    emit_bits = jnp.concatenate([nat_bits, sp_bits], axis=1)
    emit_ids = jnp.concatenate([nat_ids, sp_ids], axis=1)
    return nat_bits, emit_bits, emit_ids


def _hsbm_phase1(s_lo, s_hi, u_lo, u_hi, lb, width, *, ncells: int,
                 cap_s: int, suf_s: int, cap_u: int, suf_u: int,
                 max_pairs: int):
    """Hybrid pass 1: per-emitter counts and slot offsets.

    Emitters are the flattened per-cell tables, S side first:
    ``n_emit_s = ncells·(cap_s+suf_s)`` class-A emitters (each S emitter
    scans a window of its cell's U *natives*), then ``n_emit_u`` class-B
    emitters (window of S natives, strict-stab ranges).  Returns
    ``(sid, uid, starts, counts, offs)`` where ``sid``/``uid`` map
    emitter rows back to original region indices (−1 pads), ``starts``
    holds globalized window starts into the opposite side's emitter-table
    flat index space, and ``offs`` is the saturating exclusive scan —
    the same contract the flat ``_twopass_phase1`` feeds to pass 2.
    """
    n, m = s_lo.shape[0], u_lo.shape[0]
    s_nat_bits, s_emit_bits, s_emit_ids = _hsbm_side_tables(
        s_lo, s_hi, lb, width, ncells, cap_s, suf_s)
    u_nat_bits, u_emit_bits, u_emit_ids = _hsbm_side_tables(
        u_lo, u_hi, lb, width, ncells, cap_u, suf_u)
    ss_l = jax.vmap(partial(jnp.searchsorted, side="left"))
    ss_r = jax.vmap(partial(jnp.searchsorted, side="right"))

    # class A: u.lo ∈ [s.lo, s.hi) — window of U natives per S emitter
    s_emit_hi = jnp.where(
        s_emit_ids >= 0,
        jnp.take(s_hi, jnp.clip(s_emit_ids, 0, n - 1)), jnp.inf)
    aA = ss_l(u_nat_bits, s_emit_bits).astype(jnp.int32)
    rA = ss_l(u_nat_bits, _sortable_bits(s_emit_hi)).astype(jnp.int32)
    cnt_a = jnp.maximum(rA - aA, 0)
    # class B: u.lo < s.lo < u.hi — strict-stab window of S natives per
    # U emitter (side="right" excludes s.lo == u.lo, already class A)
    u_emit_hi = jnp.where(
        u_emit_ids >= 0,
        jnp.take(u_hi, jnp.clip(u_emit_ids, 0, m - 1)), -jnp.inf)
    bB = ss_r(s_nat_bits, u_emit_bits).astype(jnp.int32)
    cB = ss_l(s_nat_bits, _sortable_bits(u_emit_hi)).astype(jnp.int32)
    cnt_b = jnp.maximum(cB - bB, 0)

    # globalize window starts into the flat emitter index space of the
    # opposite side (row stride = natives + suffix width); windows only
    # ever cover native columns [0, cap), which occupy the row prefix
    cap_e_u = cap_u + suf_u
    cap_e_s = cap_s + suf_s
    rows = jnp.arange(ncells, dtype=jnp.int32)[:, None]
    starts = jnp.concatenate([(aA + rows * cap_e_u).reshape(-1),
                              (bB + rows * cap_e_s).reshape(-1)])
    counts = jnp.concatenate([cnt_a.reshape(-1), cnt_b.reshape(-1)])
    lim = jnp.int32(max_pairs)
    incl = jax.lax.associative_scan(
        lambda a, b: jnp.minimum(a + b, lim), jnp.minimum(counts, lim))
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), incl])
    return (s_emit_ids.reshape(-1), u_emit_ids.reshape(-1),
            starts, counts, offs)


@partial(jax.jit, static_argnames=("ncells", "cap_s", "suf_s", "cap_u",
                                   "suf_u", "max_pairs"))
def _hsbm_emit(s_lo, s_hi, u_lo, u_hi, lb, width, *, ncells: int,
               cap_s: int, suf_s: int, cap_u: int, suf_u: int,
               max_pairs: int):
    """XLA pass 2 for the hybrid: one thread per output slot.

    Identical slot arithmetic to ``_twopass_emit``; the only difference
    is that emitter/partner identities go through the ``sid``/``uid``
    tables instead of being the emitter index itself.  Returns
    ``(pairs, counts)`` — counts is the unclipped per-emitter vector for
    the host-side exact int64 K.
    """
    sid, uid, starts, counts, offs = _hsbm_phase1(
        s_lo, s_hi, u_lo, u_hi, lb, width, ncells=ncells, cap_s=cap_s,
        suf_s=suf_s, cap_u=cap_u, suf_u=suf_u, max_pairs=max_pairs)
    n_a = ncells * (cap_s + suf_s)
    n_b = ncells * (cap_u + suf_u)
    t = jnp.arange(max_pairs, dtype=jnp.int32)
    e = jnp.searchsorted(offs, t, side="right").astype(jnp.int32) - 1
    e = jnp.minimum(e, n_a + n_b - 1)
    j = t - offs[e]
    valid = (j >= 0) & (j < counts[e])
    is_a = e < n_a
    s_own = sid[jnp.minimum(e, n_a - 1)]
    u_own = uid[jnp.clip(e - n_a, 0, n_b - 1)]
    u_from_a = uid[jnp.clip(starts[e] + j, 0, n_b - 1)]
    s_from_b = sid[jnp.clip(starts[e] + j, 0, n_a - 1)]
    s_idx = jnp.where(valid, jnp.where(is_a, s_own, s_from_b), -1)
    u_idx = jnp.where(valid, jnp.where(is_a, u_from_a, u_own), -1)
    pairs = jnp.stack([s_idx, u_idx], axis=1).astype(jnp.int32)
    return pairs, counts


def hsbm_pairs(S: Regions, U: Regions, max_pairs: int,
               ncells: int | None = None):
    """Enumerate 1-D overlaps via the hybrid grid+SBM (XLA pass 2).

    Same contract as ``sbm_pairs`` (−1-padded buffer + exact python-int
    K), different pass-1 engine and emission order (cell-major).  Grid
    geometry is measured host-side per call; ``ncells`` overrides the
    heuristic cell count.
    """
    assert S.d == 1
    if S.n == 0 or U.n == 0:
        return jnp.full((max_pairs, 2), -1, jnp.int32), 0
    from .grid import hsbm_geometry
    s_lo, s_hi = S.lo[:, 0], S.hi[:, 0]
    u_lo, u_hi = U.lo[:, 0], U.hi[:, 0]
    g = hsbm_geometry(s_lo, s_hi, u_lo, u_hi, ncells=ncells)
    pairs, counts = _hsbm_emit(
        s_lo, s_hi, u_lo, u_hi, jnp.float32(g.lb), jnp.float32(g.width),
        max_pairs=max_pairs, **g.statics())
    count = int(np.sum(np.asarray(counts), dtype=np.int64))
    return pairs, count


def sbm_pairs(S: Regions, U: Regions, max_pairs: int):
    """Enumerate 1-D overlaps exactly via two-pass count-then-emit.

    Returns ``(pairs, count)``: ``pairs`` is int32 (max_pairs, 2) padded
    with −1; ``count`` is the exact total K as a python int (int64-safe),
    cross-checkable against ``sbm_count_per_sub(S, U).sum()``.  If
    ``count > max_pairs`` the buffer holds the first ``max_pairs`` pairs
    in emission order (explicit truncation — the caller decides whether
    that is an overflow).  Empty S or U returns a well-formed all-−1
    buffer with count 0.
    """
    assert S.d == 1
    if S.n == 0 or U.n == 0:
        return jnp.full((max_pairs, 2), -1, jnp.int32), 0
    pairs, cnt_a, cnt_b = _twopass_emit(
        S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0], max_pairs)
    count = int(np.sum(np.asarray(cnt_a), dtype=np.int64)
                + np.sum(np.asarray(cnt_b), dtype=np.int64))
    return pairs, count
