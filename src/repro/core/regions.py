"""Region batches for the DDM matching problem.

A *region* is a d-dimensional axis-parallel rectangle with half-open
extents ``[lo, hi)`` per dimension (paper §2).  A batch of N regions is
stored structure-of-arrays as two ``(N, d)`` float32 arrays — the layout
the TPU VPU wants (contiguous lanes per dimension), as opposed to the
paper's array-of-structs C layout.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Regions:
    """A batch of N axis-parallel d-rectangles, half-open per dimension."""

    lo: Array  # (N, d) float32
    hi: Array  # (N, d) float32

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.lo, self.hi), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        lo, hi = children
        return cls(lo=lo, hi=hi)

    # -- convenience -------------------------------------------------------
    @property
    def n(self) -> int:
        return self.lo.shape[0]

    @property
    def d(self) -> int:
        return self.lo.shape[1]

    def dim(self, k: int) -> tuple[Array, Array]:
        """1-D projection along dimension ``k`` (paper §2 reduction)."""
        return self.lo[:, k], self.hi[:, k]

    def __repr__(self) -> str:  # avoid dumping arrays
        return f"Regions(n={self.lo.shape[0]}, d={self.lo.shape[1]})"


def make_regions(lo, hi) -> Regions:
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    if lo.ndim == 1:
        lo, hi = lo[:, None], hi[:, None]
    if lo.shape != hi.shape or lo.ndim != 2:
        raise ValueError(f"bad region shapes {lo.shape} vs {hi.shape}")
    return Regions(lo=lo, hi=hi)


# ---------------------------------------------------------------------------
# Synthetic workload generators (paper §5 methodology)
# ---------------------------------------------------------------------------

def paper_workload(
    seed: int,
    n_total: int,
    alpha: float,
    space: float = 1.0e6,
    d: int = 1,
) -> tuple[Regions, Regions]:
    """The paper's synthetic benchmark (§5, after Raczy et al. [52]).

    ``n_total = N`` regions split into ``n = N/2`` subscriptions and
    ``m = N/2`` updates, each of identical length ``l = alpha * L / N``
    placed uniformly at random on a segment of length ``L = space``.
    ``alpha`` is the overlapping degree.  For ``d > 1`` every dimension is
    generated the same way (the paper evaluates d=1).
    """
    n = n_total // 2
    m = n_total - n
    length = alpha * space / n_total
    rng = np.random.default_rng(seed)

    def gen(count):
        lo = rng.uniform(0.0, space - length,
                         size=(count, d)).astype(np.float32)
        # guarantee non-empty intervals at f32: for tiny alpha*L/N the
        # exact hi = lo + length can round back onto lo near the top of
        # the domain (f32 ulp(1e6) ≈ 0.0625); the matchers' half-open
        # semantics require lo < hi (paper assumes l > 0, in doubles).
        hi = (lo.astype(np.float64) + length).astype(np.float32)
        hi = np.maximum(hi, np.nextafter(lo, np.float32(np.inf)))
        return lo, hi

    s_lo, s_hi = gen(n)
    u_lo, u_hi = gen(m)
    return (Regions(jnp.asarray(s_lo), jnp.asarray(s_hi)),
            Regions(jnp.asarray(u_lo), jnp.asarray(u_hi)))


def koln_like_workload(
    seed: int,
    n_positions: int = 541_222,
    extent: float = 20_000.0,
    width: float = 100.0,
    n_clusters: int = 64,
) -> tuple[Regions, Regions]:
    """Clustered vehicular workload mimicking the Cologne trace (§5, Fig 14).

    The public ``koln.tr`` trace is not available offline; we reproduce its
    1-D projection statistics instead: vehicle x-positions concentrated on
    a road network (mixture of dense linear clusters over a ~20 km extent),
    one subscription *and* one update region of fixed ``width`` centred on
    every position, so N ≈ 2 * n_positions regions overall.
    """
    rng = np.random.default_rng(seed)
    # road-segment mixture: cluster centres + along-road uniform spread
    centres = rng.uniform(0, extent, size=n_clusters)
    spans = rng.uniform(100.0, extent / 8, size=n_clusters)
    which = rng.integers(0, n_clusters, size=n_positions)
    x = centres[which] + rng.uniform(-0.5, 0.5, size=n_positions) * spans[which]
    x = np.clip(x, 0, extent).astype(np.float32)
    lo = (x - width / 2)[:, None]
    hi = (x + width / 2)[:, None]
    S = Regions(jnp.asarray(lo), jnp.asarray(hi))
    U = Regions(jnp.asarray(lo.copy()), jnp.asarray(hi.copy()))
    return S, U


# ---------------------------------------------------------------------------
# Shared predicate (paper Algorithm 1, half-open variant)
# ---------------------------------------------------------------------------

def intersect_1d(x_lo, x_hi, y_lo, y_hi):
    """Half-open interval overlap: [x_lo,x_hi) ∩ [y_lo,y_hi) ≠ ∅."""
    return jnp.logical_and(x_lo < y_hi, y_lo < x_hi)


@partial(jax.jit, static_argnames=())
def intersect_dd(s_lo, s_hi, u_lo, u_hi):
    """d-rectangle overlap = conjunction of per-dimension overlaps (§2)."""
    return jnp.all(jnp.logical_and(s_lo < u_hi, u_lo < s_hi), axis=-1)
