"""Interval Tree Matching (ITM) — paper §3, pointer-free TPU adaptation.

The paper's interval tree is an augmented AVL (CLRS 14.3): each node keeps
its interval plus subtree ``minlower``/``maxupper`` bounds; queries prune
subtrees whose bounds cannot overlap the query.  Build once, then all m
queries run in parallel (paper Alg. 5 line 10: ``for all u in parallel``).

TPU adaptation (DESIGN.md §2): pointers and rotations are hostile to
SIMD/MXU hardware, and the tree is *static* after construction (the paper
itself never mutates it during matching).  So we store a perfectly
balanced BST over the lo-sorted intervals in **implicit Eytzinger layout**
(node k has children 2k/2k+1) in five flat arrays, padded to a full tree
with ±inf sentinels.  The in-order position of node k in a complete tree
of height h is closed-form::

    inorder(k) = (2*(k - 2^d) + 1) * 2^(h-1-d) - 1,   d = floor(lg k)

so construction is a sort + a gather + h bottom-up max/min levels — fully
jittable, O(n lg n) like the paper's.  Queries are the standard pruned DFS
with an explicit fixed-size stack (≤ h+2 entries) inside a
``lax.while_loop``, ``vmap``-ed over all queries: the paper's
embarrassingly-parallel query loop becomes VPU-lane parallelism.  The
divergence cost of vmapped tree walks (all lanes step until the slowest
finishes) is exactly the irregularity the paper predicts for SIMD targets
in §6 — quantified in our benchmarks.

Dynamic regions (paper §3 "dynamic interval management") are handled in
``core.dynamic`` by re-querying the already-built tree of the *other* set,
which the paper shows is the dominant cost; structural insert/delete is
replaced by periodic rebuild (sort + gather), the array-native equivalent.
"""
from __future__ import annotations

from typing import NamedTuple
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .regions import Regions

Array = jax.Array


class ITree(NamedTuple):
    """Implicit interval tree.  Arrays are 1-indexed, size M+1 = 2^h."""

    lo: Array        # node interval lower bound
    hi: Array        # node interval upper bound
    minlower: Array  # subtree min lo
    maxupper: Array  # subtree max hi
    ids: Array       # original region index (−1 for sentinel)

    @property
    def height(self) -> int:
        return int(self.lo.shape[0]).bit_length() - 1  # M+1 = 2^h


@partial(jax.jit, static_argnames=("n",))
def _build(lo_1d: Array, hi_1d: Array, n: int) -> ITree:
    h = max((n).bit_length(), 1)
    if (1 << h) - 1 < n:
        h += 1
    M = (1 << h) - 1
    order = jnp.argsort(lo_1d)
    pad = M - n
    slo = jnp.concatenate([lo_1d[order],
                           jnp.full((pad,), jnp.inf, lo_1d.dtype)])
    shi = jnp.concatenate([hi_1d[order],
                           jnp.full((pad,), -jnp.inf, hi_1d.dtype)])
    sid = jnp.concatenate([order.astype(jnp.int32),
                           jnp.full((pad,), -1, jnp.int32)])

    k = jnp.arange(1, M + 1, dtype=jnp.int32)
    d = jnp.floor(jnp.log2(k.astype(jnp.float32))).astype(jnp.int32)
    # guard against float log2 edge error at exact powers of two
    d = jnp.where((1 << (d + 1)) <= k, d + 1, d)
    d = jnp.where((1 << d) > k, d - 1, d)
    j = k - (1 << d)
    inorder = (2 * j + 1) * (1 << (h - 1 - d)) - 1

    one = jnp.full((1,), 0, jnp.int32)
    tree_lo = jnp.concatenate([jnp.full((1,), jnp.inf, slo.dtype),
                               slo[inorder]])
    tree_hi = jnp.concatenate([jnp.full((1,), -jnp.inf, shi.dtype),
                               shi[inorder]])
    tree_id = jnp.concatenate([one - 1, sid[inorder]])

    maxupper = tree_hi
    minlower = tree_lo
    for lvl in range(h - 2, -1, -1):
        lo_idx, hi_idx = 1 << lvl, 1 << (lvl + 1)
        kk = jnp.arange(lo_idx, hi_idx)
        mu = jnp.maximum(maxupper[kk],
                         jnp.maximum(maxupper[2 * kk], maxupper[2 * kk + 1]))
        ml = jnp.minimum(minlower[kk],
                         jnp.minimum(minlower[2 * kk], minlower[2 * kk + 1]))
        maxupper = maxupper.at[kk].set(mu)
        minlower = minlower.at[kk].set(ml)
    return ITree(tree_lo, tree_hi, minlower, maxupper, tree_id)


def build_tree(R: Regions, dim: int = 0) -> ITree:
    lo, hi = R.dim(dim)
    return _build(lo, hi, R.n)


# ---------------------------------------------------------------------------
# queries
# ---------------------------------------------------------------------------

def _query_count_one(tree: ITree, q_lo, q_hi) -> Array:
    """Number of tree intervals overlapping [q_lo, q_hi). Scalar int32."""
    M = tree.lo.shape[0] - 1
    h = (M + 1).bit_length() - 1
    stack = jnp.zeros((h + 2,), jnp.int32).at[0].set(1)

    def cond(st):
        _, sp, _ = st
        return sp > 0

    def body(st):
        stack, sp, cnt = st
        k = stack[sp - 1]
        sp = sp - 1
        prune = (tree.maxupper[k] <= q_lo) | (tree.minlower[k] >= q_hi)
        hit = (~prune) & (tree.lo[k] < q_hi) & (q_lo < tree.hi[k]) & \
            (tree.ids[k] >= 0)
        cnt = cnt + hit.astype(jnp.int32)
        has_kids = (2 * k) <= M
        push_l = (~prune) & has_kids
        # right subtree holds lo >= node.lo: skip it if q_hi <= node.lo
        push_r = (~prune) & has_kids & (q_hi > tree.lo[k])
        stack = stack.at[sp].set(jnp.where(push_l, 2 * k, stack[sp]))
        sp = sp + push_l.astype(jnp.int32)
        stack = stack.at[sp].set(jnp.where(push_r, 2 * k + 1, stack[sp]))
        sp = sp + push_r.astype(jnp.int32)
        return stack, sp, cnt

    _, _, cnt = jax.lax.while_loop(
        cond, body, (stack, jnp.int32(1), jnp.int32(0)))
    return cnt


@jax.jit
def itm_query_counts(tree: ITree, q_lo: Array, q_hi: Array) -> Array:
    """Per-query overlap counts — paper Alg. 5 with counting Report()."""
    return jax.vmap(lambda a, b: _query_count_one(tree, a, b))(q_lo, q_hi)


def _query_pairs_one(tree: ITree, q_lo, q_hi, cap: int):
    M = tree.lo.shape[0] - 1
    h = (M + 1).bit_length() - 1
    stack = jnp.zeros((h + 2,), jnp.int32).at[0].set(1)
    buf = jnp.full((cap,), -1, jnp.int32)

    def cond(st):
        _, sp, _, _ = st
        return sp > 0

    def body(st):
        stack, sp, cnt, buf = st
        k = stack[sp - 1]
        sp = sp - 1
        prune = (tree.maxupper[k] <= q_lo) | (tree.minlower[k] >= q_hi)
        hit = (~prune) & (tree.lo[k] < q_hi) & (q_lo < tree.hi[k]) & \
            (tree.ids[k] >= 0)
        buf = jax.lax.cond(
            hit & (cnt < cap),
            lambda b: b.at[cnt].set(tree.ids[k]),
            lambda b: b, buf)
        cnt = cnt + hit.astype(jnp.int32)
        has_kids = (2 * k) <= M
        push_l = (~prune) & has_kids
        push_r = (~prune) & has_kids & (q_hi > tree.lo[k])
        stack = stack.at[sp].set(jnp.where(push_l, 2 * k, stack[sp]))
        sp = sp + push_l.astype(jnp.int32)
        stack = stack.at[sp].set(jnp.where(push_r, 2 * k + 1, stack[sp]))
        sp = sp + push_r.astype(jnp.int32)
        return stack, sp, cnt, buf

    _, _, cnt, buf = jax.lax.while_loop(
        cond, body, (stack, jnp.int32(1), jnp.int32(0), buf))
    return buf, cnt


@partial(jax.jit, static_argnames=("cap",))
def itm_query_pairs(tree: ITree, q_lo: Array, q_hi: Array, cap: int):
    """Per-query matched region ids, −1 padded, capacity ``cap``."""
    return jax.vmap(lambda a, b: _query_pairs_one(tree, a, b, cap))(
        q_lo, q_hi)


@partial(jax.jit, static_argnames=("cap",))
def itm_query_pairs_dd(tree: ITree, o_lo: Array, o_hi: Array,
                       q_lo: Array, q_hi: Array, cap: int):
    """Batched d-dim overlap query: dim-0 tree walk, then verify dims 1+.

    ``tree`` indexes dim 0 of the regions whose full coords are
    ``o_lo``/``o_hi`` (n, d); ``q_lo``/``q_hi`` are (b, d) query boxes.
    Returns ``(ids, counts)``: (b, cap) region ids overlapping each query
    on *all* dimensions (−1 padded, order-unspecified) and (b,) verified
    counts.  ``cap`` must cover the dim-0 candidate count per query
    (size it from ``itm_query_counts`` on dim 0).
    """
    ids, _ = jax.vmap(
        lambda a, b: _query_pairs_one(tree, a, b, cap))(q_lo[:, 0],
                                                        q_hi[:, 0])
    valid = ids >= 0
    ic = jnp.maximum(ids, 0)
    ok = jnp.all(
        jnp.logical_and(o_lo[ic, 1:] < q_hi[:, None, 1:],
                        q_lo[:, None, 1:] < o_hi[ic, 1:]), axis=-1)
    ok = ok & valid
    return jnp.where(ok, ids, -1), jnp.sum(ok, axis=-1, dtype=jnp.int32)


def itm_count(S: Regions, U: Regions, swap: str = "auto") -> int:
    """Total K: build tree on one set, query the other (paper Alg. 5).

    ``swap='auto'`` builds the tree on the smaller set (paper §3's
    m ≪ n optimization).
    """
    assert S.d == 1
    build_on_S = S.n <= U.n if swap == "auto" else (swap == "S")
    T = build_tree(S if build_on_S else U)
    Q = U if build_on_S else S
    counts = itm_query_counts(T, Q.lo[:, 0], Q.hi[:, 0])
    return int(np.sum(np.asarray(counts), dtype=np.int64))
