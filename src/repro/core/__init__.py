"""Core DDM matching library (the paper's contribution, in JAX).

One engine, many matchers: the paper's family of interchangeable DDM
algorithms (BFM, GBM, parallel SBM, the grid+SBM hybrid, ITM) sits
behind a single plan/compile/execute API —

    spec = MatchSpec(algo="sbm",        # bfm | gbm | sbm | sbm_chunked
                                        # | sbm_binary | hsbm | itm
                     backend="xla",     # xla | pallas | distributed
                     capacity="exact")  # exact | fixed | grow
    plan = build_plan(spec, n_sub=S.n, n_upd=U.n, d=S.d)
    k         = plan.count(S, U)        # exact K, int64-safe
    res, k    = plan.pairs(S, U)        # PairsResult (−1-padded slots)
    mask      = plan.mask(S, U)         # (n, m) bool overlap mask
    ids, cnt  = plan.query(tree, opp, q_lo, q_hi)   # dynamic service

A ``MatchSpec`` is frozen and hashable (algorithm, backend, capacity
policy, tile/block sizes, mesh); ``build_plan`` memoizes compiled plans
per problem shape, and a plan's executables are jit-cached so repeated
calls never retrace (``plan.traces`` proves it).  Pair enumeration is
the exact two-pass count-then-emit path — per-emitter counts,
exclusive-scan offsets, parallel emit — with ``algo="hsbm"`` swapping
pass 1's global sorts for coarse grid bucketing plus per-cell segmented
sorts; ``pairs()`` always returns a ``core.pairs.PairsResult`` (dense
wrapper or lazy CSR view, one consumer contract).  Under
``backend="pallas"`` the emit is one fused Mosaic kernel
(``kernels.emit``), and under ``backend="distributed"`` both the emit
and the batched dynamic-service query are sharded over a device mesh
(``core.distributed``) with set-identical results to the local
backends.

Public surface:
    MatchSpec / MatchPlan / build_plan (repro.core.engine)
    PairsResult / DensePairs — the pair-enumeration result contract
    Regions, make_regions, paper_workload, koln_like_workload
    DDMService — dynamic d-dim regions (paper §3); batched
        ``update_regions`` churn runs through the same MatchPlan
    block_mask, pairs_to_set — helpers

The pre-engine entry points (``match_count`` / ``match_pairs`` /
``distributed_sbm_count``) completed their deprecation cycle and are
removed; docs/API.md keeps the migration table.
"""
from .regions import (Regions, make_regions, paper_workload,
                      koln_like_workload, intersect_1d, intersect_dd)
from .engine import (ALGOS, BACKENDS, CAPACITY_POLICIES, MatchPlan,
                     MatchSpec, build_plan)
from .pairs import DensePairs, PairsResult
from .dd_match import block_mask, pairs_to_set
from .dynamic import (DDMService, DDMSnapshot, StoreView,
                      describe_move_index_errors)
from . import brute, grid, itm, sbm

__all__ = [
    "Regions", "make_regions", "paper_workload", "koln_like_workload",
    "intersect_1d", "intersect_dd",
    "MatchSpec", "MatchPlan", "build_plan",
    "ALGOS", "BACKENDS", "CAPACITY_POLICIES",
    "PairsResult", "DensePairs",
    "block_mask", "pairs_to_set",
    "DDMService", "DDMSnapshot", "StoreView",
    "describe_move_index_errors", "brute", "grid", "itm", "sbm",
]
