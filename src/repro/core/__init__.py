"""Core DDM matching library (the paper's contribution, in JAX).

Public surface:
    Regions, make_regions, paper_workload, koln_like_workload
    match_count / match_pairs / block_mask  (algo = bfm|gbm|sbm|itm|...)
      — pair enumeration is the exact two-pass count-then-emit path
        (per-emitter counts, exclusive-scan offsets, parallel emit)
    DDMService (dynamic d-dim regions; batched ``update_regions`` churn)
    distributed: shard_map multi-device SBM (core.distributed)
"""
from .regions import (Regions, make_regions, paper_workload,
                      koln_like_workload, intersect_1d, intersect_dd)
from .dd_match import (match_count, match_pairs, block_mask, pairs_to_set,
                       ALGOS)
from .dynamic import DDMService
from . import brute, grid, itm, sbm

__all__ = [
    "Regions", "make_regions", "paper_workload", "koln_like_workload",
    "intersect_1d", "intersect_dd", "match_count", "match_pairs",
    "block_mask", "pairs_to_set", "ALGOS", "DDMService",
    "brute", "grid", "itm", "sbm",
]
