"""Dynamic DDM service — paper §3 "dynamic interval management".

HLA federates move/resize regions constantly; rerunning the full match is
wasteful.  The paper keeps two interval trees (T_S over subscriptions,
T_U over updates): when a region of one kind changes, the overlaps of the
*changed region only* are recomputed by querying the tree of the opposite
kind — O(min{n, K lg n}) instead of a full rematch — and the changed
region is delete+reinserted into its own tree.

Array adaptation: queries use ``core.itm`` exactly as the paper does.
Structural delete+reinsert on a pointer AVL becomes *deferred rebuild*
here: the changed set's tree is marked stale and rebuilt (sort + gather,
O(n lg n), jitted) only when the next query against it arrives, amortizing
rebuilds across bursts of updates — the standard array-index equivalent.
The overlap *ledger* is a host-side sorted id set (the paper's Report()
sink is model-specific; ours returns exact added/removed pair deltas).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import itm
from .regions import Regions


class DDMService:
    """Stateful pub/sub matching service over 1-D regions."""

    def __init__(self, S: Regions, U: Regions, cap_hint: int = 64):
        assert S.d == 1 and U.d == 1
        self.s_lo = np.asarray(S.lo[:, 0]).copy()
        self.s_hi = np.asarray(S.hi[:, 0]).copy()
        self.u_lo = np.asarray(U.lo[:, 0]).copy()
        self.u_hi = np.asarray(U.hi[:, 0]).copy()
        self._tree_S = None
        self._tree_U = None
        self.cap_hint = cap_hint
        self.pairs: set[tuple[int, int]] = set()

    # -- tree cache ---------------------------------------------------------
    def _S(self) -> Regions:
        return Regions(jnp.asarray(self.s_lo)[:, None],
                       jnp.asarray(self.s_hi)[:, None])

    def _U(self) -> Regions:
        return Regions(jnp.asarray(self.u_lo)[:, None],
                       jnp.asarray(self.u_hi)[:, None])

    def tree_S(self):
        if self._tree_S is None:
            self._tree_S = itm.build_tree(self._S())
        return self._tree_S

    def tree_U(self):
        if self._tree_U is None:
            self._tree_U = itm.build_tree(self._U())
        return self._tree_U

    # -- full match (service bring-up) ---------------------------------------
    def connect(self) -> set[tuple[int, int]]:
        """Initial full match; populates the overlap ledger."""
        T = self.tree_S()
        q_lo, q_hi = jnp.asarray(self.u_lo), jnp.asarray(self.u_hi)
        counts = itm.itm_query_counts(T, q_lo, q_hi)
        cap = max(int(np.max(np.asarray(counts)) if counts.size else 0), 1)
        ids, _ = itm.itm_query_pairs(T, q_lo, q_hi, cap)
        ids = np.asarray(ids)
        self.pairs = {(int(s), int(u))
                      for u in range(ids.shape[0])
                      for s in ids[u] if s >= 0}
        return self.pairs

    # -- single-region overlap query -----------------------------------------
    def _overlaps_of(self, kind: str, lo: float, hi: float) -> set[int]:
        tree = self.tree_U() if kind == "sub" else self.tree_S()
        counts = itm.itm_query_counts(
            tree, jnp.asarray([lo], jnp.float32),
            jnp.asarray([hi], jnp.float32))
        cap = max(int(counts[0]), 1)
        ids, _ = itm.itm_query_pairs(
            tree, jnp.asarray([lo], jnp.float32),
            jnp.asarray([hi], jnp.float32), cap)
        return {int(i) for i in np.asarray(ids)[0] if i >= 0}

    # -- the dynamic operation (paper §3) --------------------------------------
    def update_region(self, kind: str, idx: int, new_lo: float,
                      new_hi: float):
        """Move/resize one region; returns (added, removed) pair deltas."""
        assert kind in ("sub", "upd")
        old = self._overlaps_of(kind, *(
            (self.s_lo[idx], self.s_hi[idx]) if kind == "sub"
            else (self.u_lo[idx], self.u_hi[idx])))
        new = self._overlaps_of(kind, new_lo, new_hi)
        if kind == "sub":
            self.s_lo[idx], self.s_hi[idx] = new_lo, new_hi
            self._tree_S = None            # deferred rebuild
            added = {(idx, u) for u in new - old}
            removed = {(idx, u) for u in old - new}
        else:
            self.u_lo[idx], self.u_hi[idx] = new_lo, new_hi
            self._tree_U = None
            added = {(s, idx) for s in new - old}
            removed = {(s, idx) for s in old - new}
        self.pairs |= added
        self.pairs -= removed
        return added, removed
