"""Dynamic DDM service — paper §3 "dynamic interval management", batched
and d-dimensional.

HLA federates move/resize regions constantly; rerunning the full match is
wasteful.  The paper keeps two interval trees (T_S over subscriptions,
T_U over updates): when a region of one kind changes, the overlaps of the
*changed region only* are recomputed by querying the tree of the opposite
kind — O(min{n, K lg n}) instead of a full rematch — and the changed
region is delete+reinserted into its own tree.

Array adaptation, three deviations from the pointer version:

* **d dimensions** via match-then-verify (``dd_match`` reduction): the
  tree indexes dim 0; candidates from the tree walk are filtered on the
  remaining dimensions with a vectorized gather + compare
  (``itm.itm_query_pairs_dd``).
* **Batched churn**: real workloads move many regions per tick.
  ``update_regions`` takes a whole batch of moved regions and runs ONE
  batched tree query (``MatchPlan.query`` — the same engine path the
  static matchers use) for all old extents plus all new extents — a
  single device round-trip per tick instead of two per region.  Moves of one
  kind never touch the tree being queried (pairs are sub×upd, and the
  opposite kind's tree is the one walked), so a batch is exactly
  equivalent to a sequence of single updates.  Because the whole tick is
  one ``plan.query``, passing ``spec=MatchSpec(algo="itm",
  backend="distributed", capacity="grow")`` shards the query batch over
  the mesh (tree replicated, queries embarrassingly parallel — paper §4's
  decomposition applied to §3's operation) with no service-code changes;
  the ``grow`` capacity is sized by a global max-count reduction so every
  device compiles one static shape.
* Structural delete+reinsert on a pointer AVL becomes *deferred rebuild*:
  the changed set's tree is marked stale and rebuilt (sort + gather,
  O(n lg n), jitted) only when the next query against it arrives,
  amortizing rebuilds across bursts of updates.

The overlap *ledger* is a host-side set of (s, u) id pairs (the paper's
Report() sink is model-specific); deltas are computed vectorized on
int64-encoded keys, not with per-region Python loops.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from . import itm
from .engine import MatchPlan, MatchSpec, build_plan
from .regions import Regions


def describe_move_index_errors(idx: np.ndarray, lo: np.ndarray,
                               hi: np.ndarray, n: int, kind: str,
                               max_report: int = 5) -> list[str]:
    """Human-readable problems in a batched ``update_regions`` request.

    The engine-side companion of ``engine.describe_pair_range_errors``:
    instead of letting a bad index silently wrap (numpy's negative
    indexing) or explode as an ``IndexError`` deep inside a jitted
    gather, every problem class names up to ``max_report`` offending
    batch slots with their values and the valid range.
    """
    def _offenders(slots, fmt):
        shown = ", ".join(fmt(int(t)) for t in slots[:max_report])
        more = (f", … {len(slots) - max_report} more"
                if len(slots) > max_report else "")
        return shown + more

    problems: list[str] = []
    bad = np.nonzero((idx < 0) | (idx >= n))[0]
    if bad.size:
        problems.append(
            f"{bad.size} {kind} move index(es) outside [0, {n}): "
            + _offenders(bad, lambda t: f"slot {t}: idx={int(idx[t])}"))
    finite = np.isfinite(lo).all(axis=-1) & np.isfinite(hi).all(axis=-1)
    bad_f = np.nonzero(~finite)[0]
    if bad_f.size:
        problems.append(
            f"{bad_f.size} move(s) with non-finite extents: "
            + _offenders(bad_f, lambda t: f"slot {t}: lo={lo[t].tolist()}, "
                                          f"hi={hi[t].tolist()}"))
    return problems


@dataclasses.dataclass(frozen=True)
class DDMSnapshot:
    """Immutable, self-contained view of one region-store version.

    Holds its *own copies* of the coordinates (host + device) plus both
    interval trees, so queries against a snapshot are stable under
    concurrent ``update_regions`` churn — a reader sees the captured
    region set in full, never a torn mix of old and new extents.  The
    serving layer's double-buffered rebuild publishes these: writers
    build a fresh snapshot off the read path and atomically swap it in.
    """

    version: int
    s_lo: np.ndarray
    s_hi: np.ndarray
    u_lo: np.ndarray
    u_hi: np.ndarray
    S: Regions
    U: Regions
    tree_S: itm.ITree
    tree_U: itm.ITree

    def target(self, kind: str) -> tuple[itm.ITree, Regions]:
        """(tree, regions) pair for querying the ``kind`` set."""
        if kind == "sub":
            return self.tree_S, self.S
        return self.tree_U, self.U

    @property
    def nbytes(self) -> int:
        """Total host + device bytes this snapshot pins.

        Sums every array leaf (host coordinate copies, device Regions,
        both interval trees) — the figure the serving layer publishes
        as the ``snapshot_bytes`` gauge so per-tenant double-buffered
        memory (live snapshot + shadow build) is observable.
        """
        leaves = jax.tree_util.tree_leaves(
            (self.s_lo, self.s_hi, self.u_lo, self.u_hi,
             self.S, self.U, self.tree_S, self.tree_U))
        return int(sum(leaf.nbytes for leaf in leaves
                       if hasattr(leaf, "nbytes")))

    def oracle_ids(self, kind: str, q_lo, q_hi) -> set[int]:
        """Brute-force ids of the ``kind`` set overlapping one box —
        the reference a served answer must match exactly."""
        lo, hi = (self.s_lo, self.s_hi) if kind == "sub" \
            else (self.u_lo, self.u_hi)
        q_lo = np.asarray(q_lo, np.float32).reshape(-1)
        q_hi = np.asarray(q_hi, np.float32).reshape(-1)
        ok = np.all((lo < q_hi[None, :]) & (q_lo[None, :] < hi), axis=-1)
        return set(np.nonzero(ok)[0].astype(int).tolist())


@dataclasses.dataclass(frozen=True)
class StoreView:
    """Cheap coordinate copy of a store at one version (capture phase).

    ``DDMService.capture()`` runs under the writer's lock in O(n) copy
    time; ``build()`` does the expensive O(n lg n) tree construction
    with no lock held — the two-phase split is what makes rebuilds
    non-blocking for both writers and readers.
    """

    version: int
    s_lo: np.ndarray
    s_hi: np.ndarray
    u_lo: np.ndarray
    u_hi: np.ndarray

    def build(self) -> DDMSnapshot:
        S = Regions(jnp.asarray(self.s_lo), jnp.asarray(self.s_hi))
        U = Regions(jnp.asarray(self.u_lo), jnp.asarray(self.u_hi))
        return DDMSnapshot(
            version=self.version,
            s_lo=self.s_lo, s_hi=self.s_hi,
            u_lo=self.u_lo, u_hi=self.u_hi,
            S=S, U=U,
            tree_S=itm.build_tree(S), tree_U=itm.build_tree(U))


class DDMService:
    """Stateful pub/sub matching service over d-dimensional regions.

    The per-tick batched tree query runs through a ``MatchPlan`` built
    from ``spec`` (default: ITM with the grow-by-doubling capacity
    policy), so the service shares the engine's compiled executables and
    capacity memoization instead of a private query path.  ``cap_hint``
    floors the per-query id-buffer capacity (rounded up to a power of
    two by the grow policy), so steady-state churn reuses one compiled
    query kernel instead of recompiling whenever the max per-query count
    drifts.  A ``spec`` with ``backend="distributed"`` runs every tick's
    batched query sharded over the mesh (``spec.mesh``, defaulting to
    all local devices); results are identical to the local backends.
    """

    def __init__(self, S: Regions, U: Regions, cap_hint: int = 64,
                 spec: MatchSpec | None = None, plan_key: Any = None):
        assert S.d == U.d, (S.d, U.d)
        self.d = S.d
        self.s_lo = np.asarray(S.lo, np.float32).copy()   # (n, d)
        self.s_hi = np.asarray(S.hi, np.float32).copy()
        self.u_lo = np.asarray(U.lo, np.float32).copy()   # (m, d)
        self.u_hi = np.asarray(U.hi, np.float32).copy()
        self._tree_S = None
        self._tree_U = None
        self.version = 0            # bumped once per applied move batch
        self.cap_hint = cap_hint
        if spec is None:
            spec = MatchSpec(algo="itm", capacity="grow",
                             max_pairs=cap_hint)
        elif spec.max_pairs is None:
            # cap_hint floors the per-query capacity unless the caller's
            # spec pins max_pairs explicitly
            spec = dataclasses.replace(spec, max_pairs=cap_hint)
        self.spec = spec
        if plan_key is None:
            # the plan is per-service (not build_plan-cached): its
            # memoized grow capacity tracks THIS service's churn history
            self.plan = MatchPlan(spec, S.n, U.n, self.d)
        else:
            # serving-layer hook: one memoized plan per (tenant, spec)
            # key, shared between the service and its server wrapper
            self.plan = build_plan(spec, S.n, U.n, self.d, key=plan_key)
        self.pairs: set[tuple[int, int]] = set()

    # -- tree cache ---------------------------------------------------------
    def _S(self) -> Regions:
        return Regions(jnp.asarray(self.s_lo), jnp.asarray(self.s_hi))

    def _U(self) -> Regions:
        return Regions(jnp.asarray(self.u_lo), jnp.asarray(self.u_hi))

    def tree_S(self):
        if self._tree_S is None:
            self._tree_S = itm.build_tree(self._S())
        return self._tree_S

    def tree_U(self):
        if self._tree_U is None:
            self._tree_U = itm.build_tree(self._U())
        return self._tree_U

    # -- shadow-rebuild support (the serving layer's double buffer) ----------
    def capture(self) -> StoreView:
        """O(n) coordinate copy of the store at its current version.

        Run this under whatever lock guards mutation; the returned
        view's ``build()`` (the O(n lg n) tree construction) needs no
        lock and never blocks readers of a previously built snapshot.
        """
        return StoreView(self.version,
                         self.s_lo.copy(), self.s_hi.copy(),
                         self.u_lo.copy(), self.u_hi.copy())

    def snapshot(self) -> DDMSnapshot:
        """Capture + build in one step (single-threaded convenience)."""
        return self.capture().build()

    def query_snapshot(self, snap: DDMSnapshot, kind: str,
                       q_lo, q_hi):
        """Batched verified ids of the ``kind`` set overlapping each of
        the (b, d) query boxes, answered *entirely from* ``snap`` — the
        live store is never read, so concurrent churn cannot tear the
        result.  Returns ``(ids (b, cap) −1-padded, counts (b,))``.
        """
        tree, opp = snap.target(kind)
        return self.plan.query(tree, opp,
                               jnp.asarray(q_lo, jnp.float32),
                               jnp.asarray(q_hi, jnp.float32))

    # -- batched verified overlap query --------------------------------------
    def _overlap_ids(self, kind: str, q_lo: np.ndarray,
                     q_hi: np.ndarray) -> np.ndarray:
        """(b, cap) −1-padded ids of the OPPOSITE kind overlapping each of
        the b query boxes, verified on all d dimensions (one
        ``MatchPlan.query`` call — the engine's dynamic-service path)."""
        if kind == "sub":
            tree, opp = self.tree_U(), self._U()
        else:
            tree, opp = self.tree_S(), self._S()
        b = q_lo.shape[0]
        if b == 0 or opp.n == 0:
            return np.full((b, 1), -1, np.int32)
        ids, _ = self.plan.query(tree, opp,
                                 jnp.asarray(q_lo, jnp.float32),
                                 jnp.asarray(q_hi, jnp.float32))
        return np.asarray(ids)

    # -- full match (service bring-up) ---------------------------------------
    def connect(self) -> set[tuple[int, int]]:
        """Initial full match; populates the overlap ledger (vectorized:
        one batched tree query over all update regions, no Python loop)."""
        ids = self._overlap_ids("upd", self.u_lo, self.u_hi)   # (m, cap)
        u_idx = np.broadcast_to(
            np.arange(ids.shape[0], dtype=np.int64)[:, None], ids.shape)
        keep = ids >= 0
        self.pairs = set(zip(ids[keep].astype(int).tolist(),
                             u_idx[keep].astype(int).tolist()))
        return self.pairs

    # -- move-batch validation ------------------------------------------------
    def _prepare_moves(self, kind: str, idx, new_lo, new_hi):
        """Validate + dedup one batched move request.

        Raises ``ValueError`` naming the offending batch slots and the
        valid index range (``describe_move_index_errors``) instead of
        letting a bad index wrap via numpy negative indexing or crash
        as an ``IndexError`` inside a jitted gather.  Duplicate indices
        keep the last occurrence (sequential "last write wins").
        """
        if kind not in ("sub", "upd"):
            raise ValueError(f"kind must be 'sub' or 'upd', got {kind!r}")
        idx = np.atleast_1d(np.asarray(idx))
        if not np.issubdtype(idx.dtype, np.integer):
            raise ValueError(
                f"move indices must be integers, got dtype {idx.dtype} "
                f"(shape {idx.shape})")
        idx = idx.astype(np.int64)
        new_lo = np.asarray(new_lo, np.float32).reshape(idx.shape[0], self.d)
        new_hi = np.asarray(new_hi, np.float32).reshape(idx.shape[0], self.d)
        n = (self.s_lo if kind == "sub" else self.u_lo).shape[0]
        problems = describe_move_index_errors(idx, new_lo, new_hi, n, kind)
        if problems:
            raise ValueError(
                f"invalid update_regions batch (b={idx.shape[0]}): "
                + "; ".join(problems))
        if idx.shape[0] == 0:
            return idx, new_lo, new_hi
        _, last = np.unique(idx[::-1], return_index=True)
        keep = np.sort(idx.shape[0] - 1 - last)
        return idx[keep], new_lo[keep], new_hi[keep]

    def _apply(self, kind: str, idx, new_lo, new_hi) -> None:
        """Write a validated move batch into the store (version bump +
        deferred tree invalidation)."""
        own_lo, own_hi = ((self.s_lo, self.s_hi) if kind == "sub"
                          else (self.u_lo, self.u_hi))
        own_lo[idx] = new_lo
        own_hi[idx] = new_hi
        self.version += 1
        if kind == "sub":
            self._tree_S = None            # deferred rebuild
        else:
            self._tree_U = None

    def apply_moves(self, kind: str, idx, new_lo, new_hi) -> int:
        """Validated coordinate update *without* delta reporting.

        The serving layer's churn path: applies the batch to the store
        (same validation and last-write-wins dedup as
        ``update_regions``) and returns the number of distinct regions
        moved, but skips the old-vs-new overlap queries that compute
        the pair ledger deltas — the server re-derives visibility from
        the next published snapshot instead.
        """
        idx, new_lo, new_hi = self._prepare_moves(kind, idx, new_lo, new_hi)
        if idx.shape[0] == 0:
            return 0
        self._apply(kind, idx, new_lo, new_hi)
        return int(idx.shape[0])

    # -- the dynamic operation (paper §3), batched -----------------------------
    def update_regions(self, kind: str, idx, new_lo, new_hi):
        """Move/resize a batch of regions of one kind in a single tick.

        ``idx`` is (b,) region indices; ``new_lo``/``new_hi`` are (b, d)
        (or (b,) when d == 1).  Returns ``(added, removed)`` — the exact
        net pair deltas, identical to applying the b single-region
        updates in sequence (duplicate indices: last occurrence wins and
        the deltas are the sequence's net effect).  A zero-churn batch
        (b == 0) is a no-op returning two empty sets.  Bad batches —
        out-of-range or non-integer indices, non-finite extents — raise
        ``ValueError`` naming the offending slots and ranges.
        """
        idx, new_lo, new_hi = self._prepare_moves(kind, idx, new_lo, new_hi)
        if idx.shape[0] == 0:
            return set(), set()
        b = idx.shape[0]

        own_lo, own_hi = ((self.s_lo, self.s_hi) if kind == "sub"
                          else (self.u_lo, self.u_hi))
        # one batched query for all old extents AND all new extents
        q_lo = np.concatenate([own_lo[idx], new_lo])
        q_hi = np.concatenate([own_hi[idx], new_hi])
        ids = self._overlap_ids(kind, q_lo, q_hi)              # (2b, cap)
        old_ids, new_ids = ids[:b], ids[b:]

        self._apply(kind, idx, new_lo, new_hi)

        # vectorized delta: encode (s, u) as s*m + u in int64, set-diff
        m = max(self.u_lo.shape[0], 1)
        moved = np.broadcast_to(idx[:, None], old_ids.shape)

        def encode(other):
            keep = other >= 0
            other64 = other[keep].astype(np.int64)
            mv = moved[keep]
            if kind == "sub":
                return mv * m + other64
            return other64 * m + mv

        old_keys = encode(old_ids)
        new_keys = encode(new_ids)
        added_k = np.setdiff1d(new_keys, old_keys)
        removed_k = np.setdiff1d(old_keys, new_keys)
        added = set(zip((added_k // m).astype(int).tolist(),
                        (added_k % m).astype(int).tolist()))
        removed = set(zip((removed_k // m).astype(int).tolist(),
                          (removed_k % m).astype(int).tolist()))
        self.pairs |= added
        self.pairs -= removed
        return added, removed

    # -- single-region compatibility wrapper -----------------------------------
    def update_region(self, kind: str, idx: int, new_lo, new_hi):
        """Move/resize one region; returns (added, removed) pair deltas."""
        return self.update_regions(
            kind, np.asarray([idx]),
            np.asarray(new_lo, np.float32).reshape(1, self.d),
            np.asarray(new_hi, np.float32).reshape(1, self.d))
