"""Dynamic DDM service — paper §3 "dynamic interval management", batched
and d-dimensional.

HLA federates move/resize regions constantly; rerunning the full match is
wasteful.  The paper keeps two interval trees (T_S over subscriptions,
T_U over updates): when a region of one kind changes, the overlaps of the
*changed region only* are recomputed by querying the tree of the opposite
kind — O(min{n, K lg n}) instead of a full rematch — and the changed
region is delete+reinserted into its own tree.

Array adaptation, three deviations from the pointer version:

* **d dimensions** via match-then-verify (``dd_match`` reduction): the
  tree indexes dim 0; candidates from the tree walk are filtered on the
  remaining dimensions with a vectorized gather + compare
  (``itm.itm_query_pairs_dd``).
* **Batched churn**: real workloads move many regions per tick.
  ``update_regions`` takes a whole batch of moved regions and runs ONE
  batched tree query (``MatchPlan.query`` — the same engine path the
  static matchers use) for all old extents plus all new extents — a
  single device round-trip per tick instead of two per region.  Moves of one
  kind never touch the tree being queried (pairs are sub×upd, and the
  opposite kind's tree is the one walked), so a batch is exactly
  equivalent to a sequence of single updates.  Because the whole tick is
  one ``plan.query``, passing ``spec=MatchSpec(algo="itm",
  backend="distributed", capacity="grow")`` shards the query batch over
  the mesh (tree replicated, queries embarrassingly parallel — paper §4's
  decomposition applied to §3's operation) with no service-code changes;
  the ``grow`` capacity is sized by a global max-count reduction so every
  device compiles one static shape.
* Structural delete+reinsert on a pointer AVL becomes *deferred rebuild*:
  the changed set's tree is marked stale and rebuilt (sort + gather,
  O(n lg n), jitted) only when the next query against it arrives,
  amortizing rebuilds across bursts of updates.

The overlap *ledger* is a host-side set of (s, u) id pairs (the paper's
Report() sink is model-specific); deltas are computed vectorized on
int64-encoded keys, not with per-region Python loops.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from . import itm
from .engine import MatchPlan, MatchSpec
from .regions import Regions


class DDMService:
    """Stateful pub/sub matching service over d-dimensional regions.

    The per-tick batched tree query runs through a ``MatchPlan`` built
    from ``spec`` (default: ITM with the grow-by-doubling capacity
    policy), so the service shares the engine's compiled executables and
    capacity memoization instead of a private query path.  ``cap_hint``
    floors the per-query id-buffer capacity (rounded up to a power of
    two by the grow policy), so steady-state churn reuses one compiled
    query kernel instead of recompiling whenever the max per-query count
    drifts.  A ``spec`` with ``backend="distributed"`` runs every tick's
    batched query sharded over the mesh (``spec.mesh``, defaulting to
    all local devices); results are identical to the local backends.
    """

    def __init__(self, S: Regions, U: Regions, cap_hint: int = 64,
                 spec: MatchSpec | None = None):
        assert S.d == U.d, (S.d, U.d)
        self.d = S.d
        self.s_lo = np.asarray(S.lo, np.float32).copy()   # (n, d)
        self.s_hi = np.asarray(S.hi, np.float32).copy()
        self.u_lo = np.asarray(U.lo, np.float32).copy()   # (m, d)
        self.u_hi = np.asarray(U.hi, np.float32).copy()
        self._tree_S = None
        self._tree_U = None
        self.cap_hint = cap_hint
        if spec is None:
            spec = MatchSpec(algo="itm", capacity="grow",
                             max_pairs=cap_hint)
        elif spec.max_pairs is None:
            # cap_hint floors the per-query capacity unless the caller's
            # spec pins max_pairs explicitly
            spec = dataclasses.replace(spec, max_pairs=cap_hint)
        self.spec = spec
        # the plan is per-service (not build_plan-cached): its memoized
        # grow capacity tracks THIS service's churn history
        self.plan = MatchPlan(spec, S.n, U.n, self.d)
        self.pairs: set[tuple[int, int]] = set()

    # -- tree cache ---------------------------------------------------------
    def _S(self) -> Regions:
        return Regions(jnp.asarray(self.s_lo), jnp.asarray(self.s_hi))

    def _U(self) -> Regions:
        return Regions(jnp.asarray(self.u_lo), jnp.asarray(self.u_hi))

    def tree_S(self):
        if self._tree_S is None:
            self._tree_S = itm.build_tree(self._S())
        return self._tree_S

    def tree_U(self):
        if self._tree_U is None:
            self._tree_U = itm.build_tree(self._U())
        return self._tree_U

    # -- batched verified overlap query --------------------------------------
    def _overlap_ids(self, kind: str, q_lo: np.ndarray,
                     q_hi: np.ndarray) -> np.ndarray:
        """(b, cap) −1-padded ids of the OPPOSITE kind overlapping each of
        the b query boxes, verified on all d dimensions (one
        ``MatchPlan.query`` call — the engine's dynamic-service path)."""
        if kind == "sub":
            tree, opp = self.tree_U(), self._U()
        else:
            tree, opp = self.tree_S(), self._S()
        b = q_lo.shape[0]
        if b == 0 or opp.n == 0:
            return np.full((b, 1), -1, np.int32)
        ids, _ = self.plan.query(tree, opp,
                                 jnp.asarray(q_lo, jnp.float32),
                                 jnp.asarray(q_hi, jnp.float32))
        return np.asarray(ids)

    # -- full match (service bring-up) ---------------------------------------
    def connect(self) -> set[tuple[int, int]]:
        """Initial full match; populates the overlap ledger (vectorized:
        one batched tree query over all update regions, no Python loop)."""
        ids = self._overlap_ids("upd", self.u_lo, self.u_hi)   # (m, cap)
        u_idx = np.broadcast_to(
            np.arange(ids.shape[0], dtype=np.int64)[:, None], ids.shape)
        keep = ids >= 0
        self.pairs = set(zip(ids[keep].astype(int).tolist(),
                             u_idx[keep].astype(int).tolist()))
        return self.pairs

    # -- the dynamic operation (paper §3), batched -----------------------------
    def update_regions(self, kind: str, idx, new_lo, new_hi):
        """Move/resize a batch of regions of one kind in a single tick.

        ``idx`` is (b,) region indices; ``new_lo``/``new_hi`` are (b, d)
        (or (b,) when d == 1).  Returns ``(added, removed)`` — the exact
        net pair deltas, identical to applying the b single-region
        updates in sequence (duplicate indices: last occurrence wins and
        the deltas are the sequence's net effect).  A zero-churn batch
        (b == 0) is a no-op returning two empty sets.
        """
        assert kind in ("sub", "upd")
        idx = np.atleast_1d(np.asarray(idx, np.int64))
        new_lo = np.asarray(new_lo, np.float32).reshape(idx.shape[0], self.d)
        new_hi = np.asarray(new_hi, np.float32).reshape(idx.shape[0], self.d)
        if idx.shape[0] == 0:
            return set(), set()
        # duplicate indices: keep the last occurrence (sequential "last
        # write wins"); deltas below are then the exact net of the sequence.
        _, last = np.unique(idx[::-1], return_index=True)
        keep = np.sort(idx.shape[0] - 1 - last)
        idx, new_lo, new_hi = idx[keep], new_lo[keep], new_hi[keep]
        b = idx.shape[0]

        own_lo, own_hi = ((self.s_lo, self.s_hi) if kind == "sub"
                          else (self.u_lo, self.u_hi))
        # one batched query for all old extents AND all new extents
        q_lo = np.concatenate([own_lo[idx], new_lo])
        q_hi = np.concatenate([own_hi[idx], new_hi])
        ids = self._overlap_ids(kind, q_lo, q_hi)              # (2b, cap)
        old_ids, new_ids = ids[:b], ids[b:]

        own_lo[idx] = new_lo
        own_hi[idx] = new_hi
        if kind == "sub":
            self._tree_S = None            # deferred rebuild
        else:
            self._tree_U = None

        # vectorized delta: encode (s, u) as s*m + u in int64, set-diff
        m = max(self.u_lo.shape[0], 1)
        moved = np.broadcast_to(idx[:, None], old_ids.shape)

        def encode(other):
            keep = other >= 0
            other64 = other[keep].astype(np.int64)
            mv = moved[keep]
            if kind == "sub":
                return mv * m + other64
            return other64 * m + mv

        old_keys = encode(old_ids)
        new_keys = encode(new_ids)
        added_k = np.setdiff1d(new_keys, old_keys)
        removed_k = np.setdiff1d(old_keys, new_keys)
        added = set(zip((added_k // m).astype(int).tolist(),
                        (added_k % m).astype(int).tolist()))
        removed = set(zip((removed_k // m).astype(int).tolist(),
                          (removed_k % m).astype(int).tolist()))
        self.pairs |= added
        self.pairs -= removed
        return added, removed

    # -- single-region compatibility wrapper -----------------------------------
    def update_region(self, kind: str, idx: int, new_lo, new_hi):
        """Move/resize one region; returns (added, removed) pair deltas."""
        return self.update_regions(
            kind, np.asarray([idx]),
            np.asarray(new_lo, np.float32).reshape(1, self.d),
            np.asarray(new_hi, np.float32).reshape(1, self.d))
