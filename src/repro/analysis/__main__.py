"""CLI: ``python -m repro.analysis [--json PATH] [--corpus DIR]``.

Exit status 0 iff the repo audit has no error findings AND (when a
corpus directory is given or the default exists) every seeded defect
was detected.  The JSON report carries both sections — CI uploads it
as the ``static-analysis`` artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .corpus import corpus_summary, corpus_to_dict, run_corpus
from .matrix import run_all


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static plan & kernel auditor (no execution).")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="write the full JSON report here")
    parser.add_argument("--corpus", metavar="DIR", default=None,
                        help="seeded-defect corpus directory (default: "
                             "tests/analysis_corpus when present; pass "
                             "'' to skip)")
    parser.add_argument("--root", metavar="DIR", default=None,
                        help="repo root for the AST lint (default: "
                             "derived from the package location)")
    args = parser.parse_args(argv)

    root = Path(args.root) if args.root else \
        Path(__file__).resolve().parents[3]

    report = run_all(root=root)
    print(report.summary())

    corpus_dir = args.corpus
    if corpus_dir is None:
        default = root / "tests" / "analysis_corpus"
        corpus_dir = str(default) if default.is_dir() else ""
    results = []
    if corpus_dir:
        results = run_corpus(corpus_dir)
        print(corpus_summary(results))

    if args.json:
        payload = report.to_dict()
        payload["corpus"] = corpus_to_dict(results)
        Path(args.json).write_text(
            json.dumps(payload, indent=2, sort_keys=True) + "\n")
        print(f"report written to {args.json}")

    failed = (not report.ok()) or any(not r.ok for r in results) \
        or (bool(corpus_dir) and not results)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
