"""Pass 4 — repo AST lint: deprecation bans + kernel-wrapper contracts.

``L_DEPRECATED``
    The pre-engine entry points (``match_count`` / ``match_pairs`` /
    ``distributed_sbm_count``) finished their deprecation cycle and
    were deleted — all code goes through the ``MatchSpec → build_plan``
    engine.  ``src/`` and ``benchmarks/`` must neither *call* these
    names nor *re-define* them (a reintroduced shim would silently
    resurrect the old API); there are no exempt definition modules
    anymore.  Tests are deliberately out of scope.

``L_EMPTY_GUARD``
    Any function that both takes a ``max_pairs`` argument and builds a
    ``pallas_call`` must short-circuit on ``max_pairs == 0`` before
    reaching the kernel: a zero-size grid is not a legal ``pallas_call``
    and the engine's empty-set contract promises a well-formed (0, 2)
    buffer.  The lint demands a literal ``max_pairs == 0`` comparison
    (either operand order) somewhere in the function body.

``L_MODULE_DOCSTRING``
    Modules under the documented subsystems (``repro/serve``,
    ``repro/analysis``) must open with a substantive module docstring
    (>= 120 characters) stating the module's contract and invariants —
    snapshot immutability, audit-pass ordering, queue bounds — not a
    one-line title.  These are the subsystems the architecture docs
    point into; an undocumented module there rots the documentation
    layer silently.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .report import Report

BANNED_CALLS = ("match_count", "match_pairs", "distributed_sbm_count")

DEFAULT_ROOTS = ("src", "benchmarks")

# subsystems whose modules must carry substantive docstrings (path
# fragments matched against the linted file's normalized path)
DOCSTRING_ROOTS = ("repro/serve", "repro/analysis")
MIN_MODULE_DOCSTRING = 120


def _call_name(node: ast.Call) -> str | None:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _has_max_pairs_arg(fn: ast.FunctionDef) -> bool:
    a = fn.args
    names = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
    return "max_pairs" in names


def _uses_pallas_call(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and _call_name(node) == "pallas_call":
            return True
    return False


def _has_empty_guard(fn: ast.FunctionDef) -> bool:
    """A literal ``max_pairs == 0`` compare anywhere in the body."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Compare) or len(node.ops) != 1:
            continue
        if not isinstance(node.ops[0], ast.Eq):
            continue
        sides = (node.left, node.comparators[0])
        has_name = any(isinstance(s, ast.Name) and s.id == "max_pairs"
                       for s in sides)
        has_zero = any(isinstance(s, ast.Constant) and s.value == 0
                       for s in sides)
        if has_name and has_zero:
            return True
    return False


def lint_source(src: str, *, path: str, report: Report) -> None:
    """Lint one module's source text (shared by repo scan and corpus)."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        report.add("lint", "L_DEPRECATED", f"{path}:{e.lineno or 0}",
                   f"unparseable module: {e.msg}")
        return

    norm = "/" + str(path).replace("\\", "/")
    if any(f"/{root}/" in norm for root in DOCSTRING_ROOTS):
        doc = ast.get_docstring(tree) or ""
        if len(doc.strip()) < MIN_MODULE_DOCSTRING:
            report.add(
                "lint", "L_MODULE_DOCSTRING", f"{path}:1",
                f"module under {DOCSTRING_ROOTS} has "
                f"{'no' if not doc else 'only a trivial'} module "
                f"docstring ({len(doc.strip())} chars < "
                f"{MIN_MODULE_DOCSTRING}) — serve/analysis modules "
                "must state their contract and invariants up front")

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = _call_name(node)
            if name in BANNED_CALLS:
                report.add(
                    "lint", "L_DEPRECATED", f"{path}:{node.lineno}",
                    f"call of removed shim '{name}' — build a "
                    "MatchPlan instead: "
                    "build_plan(MatchSpec(...), n_sub, n_upd, d)")
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name in BANNED_CALLS:
                report.add(
                    "lint", "L_DEPRECATED", f"{path}:{node.lineno}",
                    f"re-definition of removed shim '{node.name}' — the "
                    "pre-engine entry points completed their "
                    "deprecation cycle and must not be reintroduced "
                    "(see docs/API.md migration table)")

    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if (_has_max_pairs_arg(node) and _uses_pallas_call(node)
                and not _has_empty_guard(node)):
            report.add(
                "lint", "L_EMPTY_GUARD", f"{path}:{node.lineno}",
                f"'{node.name}' takes max_pairs and builds a "
                "pallas_call but never short-circuits on "
                "max_pairs == 0 — a zero-size grid is not a legal "
                "pallas_call and the engine promises a (0, 2) buffer")


def lint_paths(repo_root: str | Path, roots=DEFAULT_ROOTS, *,
               report: Report) -> int:
    """Lint every ``.py`` under ``roots``; returns files scanned."""
    repo_root = Path(repo_root)
    scanned = 0
    for root in roots:
        base = repo_root / root
        if not base.exists():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(repo_root)
            lint_source(path.read_text(), path=str(rel), report=report)
            scanned += 1
    report.note_audit("lint", f"{scanned} file(s) under {roots}")
    return scanned
