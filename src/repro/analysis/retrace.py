"""Pass 3 — retrace discipline: an enforceable guard + the grow bound.

``no_retrace``
    The engine's per-plan ``traces`` counter promoted from a number you
    *can* assert on into a context manager that *enforces* steady-state:
    any device-side retrace by the guarded plans inside the block raises
    ``RetraceError`` naming which executables traced (from the plan's
    ``trace_log``).  Production call sites wrap their steady-state loops;
    tests wrap a second identical call.

``audit_grow_bound``
    The ``capacity="grow"`` contract is that a stream of calls with K
    drifting up to ``max_k`` retraces O(lg K) times total — the
    power-of-two memoized capacity ladder.  The audit drives a plan's
    capacity resolver (pure host code — nothing traces) through an
    adversarial K stream: a dense low ramp, a geometric climb to
    ``max_k``, and a descending tail that catches resolvers whose
    capacity is not monotone (oscillating capacities retrace forever).
    Distinct resolved capacities must stay within
    ``ceil(lg max_k) + 2``; ``R_GROW_BOUND`` otherwise.
"""
from __future__ import annotations

import contextlib
import math

from .report import Report


class RetraceError(AssertionError):
    """A guarded plan retraced inside a ``no_retrace`` block."""


@contextlib.contextmanager
def no_retrace(*plans, allow: int = 0):
    """Fail loudly if any of ``plans`` retraces inside the block.

    ``allow`` permits that many traces total (e.g. ``allow=1`` for a
    block expected to compile exactly once).  On violation the error
    lists, per plan, the executables that traced — the plan's
    ``trace_log`` delta — so the offending shape or capacity change is
    immediately attributable.
    """
    before = [(p, p.traces, len(p.trace_log)) for p in plans]
    yield
    total = sum(p.traces - t0 for p, t0, _ in before)
    if total > allow:
        detail = []
        for p, t0, l0 in before:
            delta = p.traces - t0
            if delta:
                names = ", ".join(p.trace_log[l0:]) or "<unnamed>"
                detail.append(f"{p!r} traced {delta}x ({names})")
        raise RetraceError(
            f"{total} retrace(s) inside a no_retrace block "
            f"(allowed {allow}): " + "; ".join(detail))


def grow_bound(max_k: int) -> int:
    """Permitted distinct capacities for a grow resolver up to ``max_k``."""
    return max(1, math.ceil(math.log2(max(max_k, 2)))) + 2


def adversarial_k_stream(max_k: int) -> list[int]:
    """Dense low ramp + linear sweep + geometric climb + descending tail.

    The linear sweep (256 evenly spaced K values) is what separates a
    doubling ladder (≤ lg K distinct capacities over the whole sweep)
    from any resolver whose capacity grows linearly in K, however
    coarsely quantized; the tail re-presents earlier Ks so capacities
    that are not monotone-memoized surface as extra distinct values.
    """
    ks = list(range(1, min(max_k, 257) + 1))
    step = max(1, max_k // 256)
    ks.extend(range(step, max_k + 1, step))
    k = 256
    while k < max_k:
        k = min(k * 2 + k // 3, max_k)   # off-power-of-two growth
        ks.append(k)
    ks.extend(ks[::-3] or [1])           # descending tail (non-monotone K)
    return [min(max(k, 1), max_k) for k in ks]


def audit_grow_bound(resolver_factory, *, max_k: int, target: str,
                     report: Report) -> None:
    """Check one capacity resolver against the O(lg K) retrace bound.

    ``resolver_factory()`` must return a *fresh* stateful resolver
    ``f(exact_k) -> capacity`` (for the engine:
    ``MatchPlan(...)._resolve_cap``).  Every distinct returned capacity
    is one compile of the pairs executable; exceeding ``grow_bound``
    means steady-state churn keeps recompiling.
    """
    resolve = resolver_factory()
    caps: list[int] = []
    seen: set[int] = set()
    for k in adversarial_k_stream(max_k):
        cap = int(resolve(k))
        if cap not in seen:
            seen.add(cap)
            caps.append(cap)
    bound = grow_bound(max_k)
    if len(seen) > bound:
        head = ", ".join(str(c) for c in caps[:12])
        more = f", … {len(caps) - 12} more" if len(caps) > 12 else ""
        report.add(
            "retrace", "R_GROW_BOUND", target,
            f"{len(seen)} distinct capacities over a K-stream up to "
            f"{max_k} (bound: ceil(lg K) + 2 = {bound}); each one is a "
            f"recompile — capacities: {head}{more}")
    report.note_audit("retrace", f"{target} (max_k={max_k})")


def engine_grow_resolver_factory(spec_kwargs: dict | None = None,
                                 n: int = 64, m: int = 64):
    """Fresh ``_resolve_cap`` bound to a new grow-capacity ``MatchPlan``."""
    from ..core.engine import MatchPlan, MatchSpec

    def factory():
        spec = MatchSpec(capacity="grow", **(spec_kwargs or {}))
        return MatchPlan(spec, n, m, 1)._resolve_cap

    return factory
