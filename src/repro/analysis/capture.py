"""Capture hooks: record what the engine and the kernels actually run.

The auditor never re-implements dispatch.  Instead it *records* the real
thing at two choke points and re-traces what it recorded abstractly:

* ``capture_plan_executables`` — installs ``core.engine._JIT_CAPTURE_HOOK``
  so every per-plan jitted executable records ``(plan, name, fn,
  static_argnames, args, kwargs)`` at call time.  A tiny concrete probe
  run through the real ``MatchPlan`` methods then yields, for every
  algo × backend × capacity row, exactly the device functions that row
  executes — with example arguments whose shapes the audit can
  re-abstract (and re-scale) for ``jax.make_jaxpr``.

* ``capture_pallas_calls`` — monkeypatches ``pl.pallas_call`` so any
  trace (e.g. ``jax.eval_shape`` of a kernel wrapper) records the grid,
  BlockSpecs, scratch shapes, and operand avals the wrapper really
  passes.  Because the capture happens *during abstract tracing*, the
  kernels are never executed — a 2e6-region streaming emit is audited
  in milliseconds with zero device memory.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core import engine


# ---------------------------------------------------------------------------
# engine executables
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CapturedCall:
    """One call into a per-plan jitted executable."""

    plan: Any               # the MatchPlan
    name: str               # executable name (engine's _jitted key)
    fn: Callable            # the *unjitted* underlying function
    static_argnames: tuple  # names passed statically (always by keyword)
    args: tuple             # concrete positional arguments (pytrees)
    kwargs: dict            # concrete keyword arguments

    @property
    def target(self) -> str:
        s = self.plan.spec
        return (f"{s.algo}/{s.backend}/{s.capacity}:{self.name}")

    def split_kwargs(self) -> tuple[dict, dict]:
        """(static_kwargs, traced_kwargs)."""
        static = {k: v for k, v in self.kwargs.items()
                  if k in self.static_argnames}
        traced = {k: v for k, v in self.kwargs.items()
                  if k not in self.static_argnames}
        return static, traced


@contextlib.contextmanager
def capture_plan_executables(records: list[CapturedCall]):
    """Route every newly-built plan executable through a recorder.

    Only plans *constructed inside* the context are captured (existing
    plans keep their warm caches) — the audit builds fresh ``MatchPlan``
    instances, bypassing the ``build_plan`` memo, so production plans
    are never touched.
    """
    def hook(plan, name, fn, static_argnames, jitted):
        def recording(*args, **kw):
            records.append(CapturedCall(plan, name, fn,
                                        tuple(static_argnames), args, kw))
            return jitted(*args, **kw)
        return recording

    prev = engine._JIT_CAPTURE_HOOK
    engine._JIT_CAPTURE_HOOK = hook
    try:
        yield records
    finally:
        engine._JIT_CAPTURE_HOOK = prev


def _is_arraylike(x) -> bool:
    return isinstance(x, (jax.Array, np.ndarray)) or (
        hasattr(x, "shape") and hasattr(x, "dtype"))


def abstractify(tree, dim_map: Callable[[int], int] | None = None):
    """Array leaves → ``ShapeDtypeStruct``; everything else unchanged.

    ``dim_map`` optionally rewrites every dimension size (the audit's
    probe→target scaling); identity when omitted.
    """
    def leaf(x):
        if _is_arraylike(x):
            shape = tuple((dim_map(int(d)) if dim_map else int(d))
                          for d in x.shape)
            return jax.ShapeDtypeStruct(shape, x.dtype)
        return x

    return jax.tree_util.tree_map(leaf, tree)


# ---------------------------------------------------------------------------
# pallas_call sites
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KernelCapture:
    """One ``pallas_call`` invocation, normalized across grid-spec styles."""

    kernel_name: str
    grid: tuple
    in_specs: tuple          # BlockSpec per (non-scalar-prefetch) operand
    out_specs: tuple         # BlockSpec per output
    scratch_shapes: tuple    # MemoryRef-likes
    num_scalar_prefetch: int
    operands: tuple          # ShapeDtypeStruct per operand (all of them)
    out_shapes: tuple        # ShapeDtypeStruct per output
    interpret: bool = False

    @property
    def target(self) -> str:
        return f"pallas_call:{self.kernel_name}"


def _as_tuple(x) -> tuple:
    if x is None:
        return ()
    if isinstance(x, (list, tuple)):
        return tuple(x)
    return (x,)


def _normalize(kernel, kw, operands) -> KernelCapture:
    name = getattr(kernel, "__name__", None)
    if name is None:  # functools.partial
        name = getattr(getattr(kernel, "func", None), "__name__", str(kernel))
    gs = kw.get("grid_spec")
    if gs is not None:
        grid = tuple(getattr(gs, "grid", ()) or ())
        in_specs = _as_tuple(getattr(gs, "in_specs", ()))
        out_specs = _as_tuple(getattr(gs, "out_specs", ()))
        scratch = _as_tuple(getattr(gs, "scratch_shapes", ()))
        nsp = int(getattr(gs, "num_scalar_prefetch", 0) or 0)
    else:
        grid = tuple(_as_tuple(kw.get("grid", ())))
        in_specs = _as_tuple(kw.get("in_specs", ()))
        out_specs = _as_tuple(kw.get("out_specs", ()))
        scratch = _as_tuple(kw.get("scratch_shapes", ()))
        nsp = 0
    out_shapes = tuple(
        jax.ShapeDtypeStruct(o.shape, o.dtype)
        for o in _as_tuple(kw.get("out_shape")))
    avals = tuple(jax.ShapeDtypeStruct(jnp.shape(o),
                                       jnp.result_type(o))
                  for o in operands)
    return KernelCapture(
        kernel_name=str(name), grid=grid, in_specs=in_specs,
        out_specs=out_specs, scratch_shapes=scratch,
        num_scalar_prefetch=nsp, operands=avals, out_shapes=out_shapes,
        interpret=bool(kw.get("interpret", False)))


@contextlib.contextmanager
def capture_pallas_calls(records: list[KernelCapture]):
    """Record every ``pl.pallas_call`` built while the context is live.

    All repo kernels call through the ``pl`` module attribute, so one
    patch point covers every kernel file.  The wrapped call still
    builds the real ``pallas_call`` — tracing (``jax.eval_shape`` /
    ``jax.make_jaxpr``) proceeds normally, it is just observed.
    """
    real = pl.pallas_call

    def patched(kernel, **kw):
        inner = real(kernel, **kw)

        def call(*operands):
            records.append(_normalize(kernel, kw, operands))
            return inner(*operands)

        return call

    pl.pallas_call = patched
    try:
        yield records
    finally:
        pl.pallas_call = real


def trace_kernel(fn: Callable, *abstract_args,
                 **abstract_kwargs) -> list[KernelCapture]:
    """``jax.eval_shape`` the wrapper, returning its pallas captures.

    ``jax.eval_shape`` memoizes jaxprs, so a repeat trace of the same
    wrapper at the same shapes would never re-run its Python body — and
    the patched ``pallas_call`` would record nothing.  The capture only
    exists while the body actually executes, so flush the trace caches
    first: an audit trace must always be fresh.
    """
    jax.clear_caches()
    records: list[KernelCapture] = []
    with capture_pallas_calls(records):
        jax.eval_shape(fn, *abstract_args, **abstract_kwargs)
    return records
