"""The repo's audit matrix — what ``python -m repro.analysis`` verifies.

Four coordinated sweeps:

* ``audit_plan_matrix`` — every registered algo × backend × capacity
  row: a fresh ``MatchPlan`` runs a tiny concrete probe (distinct prime
  sizes) under the engine capture hook, and every executable the row
  actually dispatched is re-traced abstractly at the row's *target*
  scale (the paper's N ≥ 1e6 regime for the sort-based paths; the
  largest int32-safe mask for the brute-force family) and audited.
* ``audit_ops_hotpaths`` — the pallas backend routes around the
  engine's per-plan jit cache through module-level jits in
  ``kernels.ops``; those are declared targets audited at target scale
  directly.
* ``audit_kernel_matrix`` — every ``pallas_call`` in ``kernels/``
  traced at production scale and statically checked (footprint, index
  maps, hazards), plus the emit-route byte-model parity assertion.
* ``audit_retrace_matrix`` — the grow-capacity resolvers against the
  O(lg K) bound, and a live steady-state ``no_retrace`` probe.

Probe sizes are distinct primes so captured dimensions resolve to
unique symbolic meanings (see ``jaxpr_audit.scale_dims``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core import itm
from ..core.engine import (ALGOS, BACKENDS, CAPACITY_POLICIES, MatchPlan,
                           MatchSpec)
from ..core.regions import Regions
from .capture import capture_plan_executables, trace_kernel
from .jaxpr_audit import audit_captured_call, audit_fn
from .kernel_audit import (audit_emit_route_parity, audit_kernel_capture)
from .report import Report
from .retrace import (RetraceError, audit_grow_bound,
                      engine_grow_resolver_factory, no_retrace)

# distinct primes: every derived dimension of a captured argument
# (n, m, n+m, n+m+1, caps, products …) resolves uniquely
PROBE = {"n": 37, "m": 29, "cap": 53}

# per-algorithm target scales for the abstract re-trace.  The brute
# family materializes (n, m) masks, so its target is the largest
# int32-safe mask; the sort-based paths scale to the paper's regime.
_BRUTE_TARGET = {"n": 30_000, "m": 30_000, "cap": 1 << 20}
_SORT_TARGET = {"n": 1_000_000, "m": 1_000_000, "cap": 1 << 21}
TARGETS = {
    "bfm": _BRUTE_TARGET,
    "gbm": _BRUTE_TARGET,
    "sbm": _SORT_TARGET,
    "sbm_chunked": _SORT_TARGET,
    "sbm_binary": _SORT_TARGET,
    "hsbm": _SORT_TARGET,
    "itm": _SORT_TARGET,
}

# declared output-dtype contracts per engine executable (None = any)
I32 = np.int32
OUT_DTYPES = {
    "mask": (np.bool_,),
    "bfm_count": (I32,),
    "bfm_pairs": (I32, I32),
    "sbm_contribs": (I32,),
    "sbm_chunked": (I32,),
    "sbm_per_sub": (I32,),
    "cand_per_sub": (I32,),
    "twopass_emit": (I32, I32, I32),
    "hsbm_tables": (I32, I32, I32, I32, I32),
    "hsbm_emit": (I32, I32),
    "itm_counts": (I32,),
    "itm_flatten": (I32,),
    "itm_query_dd": (I32, I32),
    "verify": (I32, I32),
    "dist_pairs_pass1": (I32, np.float32, I32, np.float32, I32, I32),
    "dist_pairs_emit": (I32, I32),
    "dist_query_counts": (I32,),
    "dist_query": (I32, I32),
}


def probe_regions(n: int, d: int = 1, seed: int = 0) -> Regions:
    rng = np.random.RandomState(seed)
    lo = rng.uniform(0.0, 1.0, size=(n, d)).astype(np.float32)
    ext = rng.uniform(0.01, 0.2, size=(n, d)).astype(np.float32)
    return Regions(jnp.asarray(lo), jnp.asarray(lo + ext))


def iter_plan_rows():
    """Every registered (algo, backend, capacity) combination."""
    for algo in ALGOS:
        for backend in BACKENDS:
            if backend == "distributed" and algo not in (
                    "sbm", "sbm_chunked", "sbm_binary"):
                continue  # engine: distributed implements parallel SBM
            for capacity in CAPACITY_POLICIES:
                yield algo, backend, capacity


def _row_spec(algo: str, backend: str, capacity: str) -> MatchSpec:
    kw = dict(algo=algo, backend=backend, capacity=capacity,
              interpret=True)
    if capacity == "fixed":
        kw["max_pairs"] = PROBE["cap"]
    return MatchSpec(**kw)


def _dedupe_key(call):
    shapes = tuple(
        (tuple(a.shape), str(a.dtype))
        if hasattr(a, "shape") and hasattr(a, "dtype") else repr(a)
        for a in jax.tree_util.tree_leaves((call.args, call.kwargs)))
    static_kw, _ = call.split_kwargs()
    return (call.target, tuple(sorted(
        (k, repr(v)) for k, v in static_kw.items())), shapes)


def audit_plan_matrix(report: Report, *, rows=None) -> None:
    """Probe + abstractly audit every engine matrix row."""
    S = probe_regions(PROBE["n"], seed=0)
    U = probe_regions(PROBE["m"], seed=1)

    for algo, backend, capacity in (rows or iter_plan_rows()):
        spec = _row_spec(algo, backend, capacity)
        # fresh plan, bypassing the warm build_plan memo, so the probe
        # really traces (and therefore really captures) every path
        plan = MatchPlan(spec, S.n, U.n, 1)
        records = []
        with capture_plan_executables(records):
            plan.count(S, U)
            plan.pairs(S, U)
            if backend != "distributed":
                plan.mask(S, U)
            if algo == "itm" or backend == "distributed":
                tree = itm.build_tree(
                    Regions(S.lo[:, :1], S.hi[:, :1]))
                plan.query(tree, S, U.lo, U.hi)

        row = f"{algo}/{backend}/{capacity}"
        seen = set()
        for call in records:
            key = _dedupe_key(call)
            if key in seen:
                continue
            seen.add(key)
            audit_captured_call(
                call, report=report, probe=PROBE,
                target_scale=TARGETS[algo],
                out_dtypes=OUT_DTYPES.get(call.name))
        report.note_audit(
            "jaxpr", f"row {row}: {len(seen)} executable(s)")


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _csr_abstract_args(n: int, m: int, *, block: int):
    """Abstract (tab, perm_s_pad, perm_u_pad, w0) for the csr kernel.

    Mirrors the shapes ``ops._csr_tables`` hands to
    ``emit.csr_decode_window``: the packed table floored at the DMA
    window, the permutations padded for fixed-run over-reads, and the
    dynamic window-start scalar.
    """
    from ..kernels import emit as emit_kernel

    bl = emit_kernel.lane_pad(block)
    win = emit_kernel.stream_window(bl)
    e = n + m
    e_pad = e + max((-e) % 128, win - e)
    return (_i32(8, e_pad), _i32(1, emit_kernel.lane_pad(n + bl)),
            _i32(1, emit_kernel.lane_pad(m + bl)), _i32())


def audit_ops_hotpaths(report: Report) -> None:
    """Target-scale jaxpr audit of the pallas backend's module jits."""
    from ..kernels import emit as emit_kernel
    from ..kernels import ops

    nb, mb = 30_720, 30_720           # brute family: 256-multiples,
    #                                   n*m just under the int32 bound
    ns = ms = 1_000_000               # sort family: the paper's regime
    nc = mc = 5_000_000               # csr route: the 1e7 regime
    e = ns + ms

    entries = [
        ("ops._tile_counts", ops._tile_counts,
         (_f32(nb, 2), _f32(nb, 2), _f32(mb, 2), _f32(mb, 2)),
         dict(ts=256, tu=256, interpret=True), (I32,)),
        ("ops._mask_padded", ops._mask_padded,
         (_f32(nb, 2), _f32(nb, 2), _f32(mb, 2), _f32(mb, 2)),
         dict(ts=256, tu=256, interpret=True), (np.bool_,)),
        ("ops._compact_mask_pairs", ops._compact_mask_pairs,
         (jax.ShapeDtypeStruct((nb, mb), jnp.bool_),),
         dict(max_pairs=4096), (I32, I32)),
        ("ops._twopass_tables", ops._twopass_tables,
         (_f32(ns), _f32(ns), _f32(ms), _f32(ms)),
         dict(max_pairs=1 << 21), None),
        # hybrid pass 1 at the same 1e6 regime: geometry statics match
        # what hsbm_geometry measures for the uniform paper workload
        # (ncells = pow2((n+m)/1280), ~64-granular per-cell caps)
        ("ops._hsbm_tables", ops._hsbm_tables,
         (_f32(ns), _f32(ns), _f32(ms), _f32(ms), _f32(), _f32()),
         dict(ncells=2048, cap_s=640, suf_s=64, cap_u=640, suf_u=64,
              max_pairs=1 << 21), (I32, I32, I32, I32, I32)),
        ("ops._hsbm_csr_tables", ops._hsbm_csr_tables,
         (_f32(nc), _f32(nc), _f32(mc), _f32(mc), _f32(), _f32()),
         dict(ncells=8192, cap_s=768, suf_s=64, cap_u=768, suf_u=64,
              max_pairs=1 << 21, block=512), None),
        ("ops._sweep", ops._sweep,
         (_f32(ns), _f32(ns), _f32(ms), _f32(ms)),
         dict(block=2048, interpret=True), (I32,)),
        ("emit.twopass_emit", emit_kernel.twopass_emit,
         (_i32(e + 1), _i32(e), _i32(e), _i32(ns), _i32(ms)),
         dict(n=ns, m=ms, max_pairs=1 << 21, block=512,
              interpret=True), (I32,)),
        ("emit.twopass_emit_streaming",
         emit_kernel.twopass_emit_streaming,
         (_i32(e + 1), _i32(e), _i32(e), _i32(ns), _i32(ms)),
         dict(n=ns, m=ms, max_pairs=1 << 21, block=512,
              interpret=True), (I32,)),
        # csr route at its own regime: n+m = 1e7, past both dense
        # Pallas routes' budgets
        ("ops._csr_tables", ops._csr_tables,
         (_f32(nc), _f32(nc), _f32(mc), _f32(mc)),
         dict(max_pairs=1 << 21, block=512), None),
        ("emit.csr_decode_window", emit_kernel.csr_decode_window,
         _csr_abstract_args(nc, mc, block=512),
         dict(n=nc, m=mc, nslots=1 << 16, block=512,
              interpret=True), (I32,)),
    ]
    for name, fn, args, static_kw, out_dtypes in entries:
        audit_fn(fn, args, target=name, report=report,
                 static_kwargs=static_kw, out_dtypes=out_dtypes)


def kernel_matrix_entries():
    """(name, traced wrapper, abstract args) for every Pallas kernel."""
    from ..kernels import bfm as bfm_kernel
    from ..kernels import emit as emit_kernel
    from ..kernels import sbm_sweep as sweep_kernel
    from ..kernels import sparse_attn

    nr = mr = 100_000                  # resident-regime emit
    ns = ms = 1_000_000                # streaming-regime emit
    nc = mc = 5_000_000                # csr-regime emit (1e7 total)
    nb = mb = 30_720                   # brute family (256-multiples)
    sweep_len = 2048 * 2049            # ≈ 2(n+m) at 1e6, block-aligned
    BH, Sq, dh = 8, 2048, 128

    def emit_args(n, m, cap):
        return (_i32(n + m + 1), _i32(n + m), _i32(n + m),
                _i32(n), _i32(m))

    return [
        ("emit_resident",
         functools.partial(emit_kernel.twopass_emit, n=nr, m=mr,
                           max_pairs=1 << 20, block=512),
         emit_args(nr, mr, 1 << 20)),
        ("emit_streaming",
         functools.partial(emit_kernel.twopass_emit_streaming, n=ns,
                           m=ms, max_pairs=1 << 21, block=512),
         emit_args(ns, ms, 1 << 21)),
        ("emit_csr_decode",
         functools.partial(emit_kernel.csr_decode_window, n=nc, m=mc,
                           nslots=1 << 16, block=512),
         _csr_abstract_args(nc, mc, block=512)),
        ("bfm_tile_counts",
         functools.partial(bfm_kernel.bfm_tile_counts, ts=256, tu=256),
         (_f32(nb, 2), _f32(nb, 2), _f32(mb, 2), _f32(mb, 2))),
        ("bfm_mask",
         functools.partial(bfm_kernel.bfm_mask, ts=256, tu=256),
         (_f32(nb, 2), _f32(nb, 2), _f32(mb, 2), _f32(mb, 2))),
        ("sbm_sweep",
         functools.partial(sweep_kernel.sbm_sweep, block=2048),
         (_i32(sweep_len), _i32(sweep_len))),
        ("sparse_attn",
         functools.partial(sparse_attn._sparse_attn_bh, bq=128,
                           bkv=128, sink_end=256, interpret=False),
         (_f32(BH, Sq, dh), _f32(BH, Sq, dh), _f32(BH, Sq, dh),
          _i32(Sq // 128), _i32(Sq // 128))),
    ]


def audit_kernel_matrix(report: Report) -> None:
    """Static pallas_call checks at production scale + route parity."""
    for name, fn, args in kernel_matrix_entries():
        caps = trace_kernel(fn, *args)
        if not caps:
            report.add(
                "kernel", "K_NO_CAPTURE", name,
                "tracing this kernel wrapper produced no pallas_call — "
                "the audit lost coverage of it (wrapper renamed or "
                "short-circuited?)")
            continue
        for cap in caps:
            audit_kernel_capture(cap, report=report)
    audit_emit_route_parity(report)


def audit_retrace_matrix(report: Report) -> None:
    """Grow-capacity bounds + a live steady-state no_retrace probe."""
    audit_grow_bound(
        engine_grow_resolver_factory(), max_k=1 << 20,
        target="MatchPlan._resolve_cap[grow]", report=report)

    def query_factory():
        plan = MatchPlan(MatchSpec(capacity="grow"), 64, 64, 1)
        return plan._resolve_query_cap

    audit_grow_bound(
        query_factory, max_k=1 << 20,
        target="MatchPlan._resolve_query_cap[grow]", report=report)

    def cap_dev_factory():
        # per-device emit capacity of the distributed backend: drifting
        # per-device pair totals must ride the same pow2 memo ladder
        plan = MatchPlan(MatchSpec(backend="distributed",
                                   capacity="grow"), 64, 64, 1)
        return plan._resolve_cap_dev

    audit_grow_bound(
        cap_dev_factory, max_k=1 << 20,
        target="MatchPlan._resolve_cap_dev[grow]", report=report)

    # live steady state: the second identical call must not retrace.
    # hsbm re-measures its grid geometry per call on the host, so the
    # probe additionally proves stable geometry ⇒ stable statics.
    S = probe_regions(PROBE["n"], seed=0)
    U = probe_regions(PROBE["m"], seed=1)
    for algo in ("sbm", "hsbm"):
        plan = MatchPlan(MatchSpec(algo=algo, capacity="grow"),
                         S.n, U.n, 1)
        plan.count(S, U)
        plan.pairs(S, U)
        try:
            with no_retrace(plan):
                plan.count(S, U)
                plan.pairs(S, U)
        except RetraceError as e:
            report.add("retrace", "R_STEADY_STATE",
                       f"{algo}/xla/grow steady state", str(e))
    report.note_audit("retrace",
                      "steady-state no_retrace probes (sbm, hsbm)")


def run_all(*, root=None) -> Report:
    """The full static audit: all four passes over the repo matrix."""
    from pathlib import Path

    from .lint import lint_paths

    report = Report()
    audit_plan_matrix(report)
    audit_ops_hotpaths(report)
    audit_kernel_matrix(report)
    audit_retrace_matrix(report)
    root = root or Path(__file__).resolve().parents[3]
    lint_paths(root, report=report)
    return report
