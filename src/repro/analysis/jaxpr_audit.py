"""Pass 1 — jaxpr-level audit of every plan executable.

Each captured plan executable (see ``capture``) is re-traced abstractly
with ``jax.make_jaxpr`` — no device buffers, no execution — and its
jaxpr is walked (recursively through ``pjit``/scan/cond sub-jaxprs) for
statically-decidable hazards:

``J_INT32_INDEX``
    An int32 ``iota`` wider than ``INT32_MAX``.  Every XLA index-space
    builder the engine leans on — ``argsort``, ``arange``, ``nonzero``,
    the flat mask compaction — lowers to an int32 iota over the index
    domain, so an over-wide iota is exactly the "pair offsets overflow
    int32" defect of the paper's N ≥ 1e6 regime scaled further up.
    Detection is on the *scaled* trace: probe shapes are re-mapped to
    the matrix row's target sizes first (see ``scale_dims``).

``J_F64`` / ``J_WEAK_OUT`` / ``J_DTYPE_CONTRACT``
    Any float64 value inside a traced hot path (the whole repo contract
    is f32/int32); weak-typed outputs (silent promotion hazard for
    callers doing arithmetic on results); outputs whose dtype differs
    from the method's declared contract (pairs/ids are int32, counts
    int32/int64, masks bool).

``J_RANK_PROMOTION``
    The same trace repeated under ``jax.numpy_rank_promotion("raise")``;
    an error means some op relies on implicit rank promotion.

``J_CALLBACK``
    Host callbacks or device transfers (``pure_callback``,
    ``io_callback``, ``debug_callback``, ``device_put``, infeed/outfeed)
    anywhere in a jitted hot path.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from .capture import CapturedCall, abstractify
from .report import Report

INT32_MAX = np.iinfo(np.int32).max

# primitives that move data off the device or into Python at run time
CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "python_callback", "host_callback_call", "outside_call",
    "device_put", "infeed", "outfeed", "copy_to_host_async",
})

_SUBJAXPR_SKIP_F64 = frozenset()   # (reserved: passes that allow f64)


def _subjaxprs_of(params):
    """Sub-jaxprs referenced from an eqn's params (pjit/scan/cond…)."""
    from jax.core import ClosedJaxpr, Jaxpr
    for v in params.values():
        if isinstance(v, Jaxpr):
            yield v
        elif isinstance(v, ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, (tuple, list)):
            for w in v:
                if isinstance(w, Jaxpr):
                    yield w
                elif isinstance(w, ClosedJaxpr):
                    yield w.jaxpr


def walk_eqns(jaxpr):
    """Yield every eqn in ``jaxpr`` and (recursively) its sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _subjaxprs_of(eqn.params):
            yield from walk_eqns(sub)


def _benign_device_put(eqn) -> bool:
    """Constant placement, not a transfer.

    jnp constants inside jit lower to ``device_put`` eqns with no
    device target (``devices=[None]``, ``srcs=[None]``); an actual
    ``jax.device_put(x, device)`` in a traced path carries a concrete
    target and IS flagged.
    """
    if eqn.primitive.name != "device_put":
        return False
    devices = eqn.params.get("devices", [])
    srcs = eqn.params.get("srcs", [])
    return all(d is None for d in devices) and all(
        s is None for s in srcs)


def _avals(eqn):
    for v in list(eqn.invars) + list(eqn.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "dtype"):
            yield aval


def audit_closed_jaxpr(closed, *, target: str, report: Report,
                       out_dtypes: tuple | None = None) -> None:
    """Walk one traced jaxpr for the static hazard classes above."""
    jaxpr = closed.jaxpr

    for eqn in walk_eqns(jaxpr):
        prim = eqn.primitive.name
        if prim == "iota":
            dt = np.dtype(eqn.params.get("dtype", np.int32))
            shape = eqn.params.get("shape", ())
            dim = eqn.params.get("dimension", 0)
            if dt == np.int32 and shape and shape[dim] > INT32_MAX:
                report.add(
                    "jaxpr", "J_INT32_INDEX", target,
                    f"int32 iota over {shape[dim]} elements "
                    f"(> INT32_MAX = {INT32_MAX}): index computations on "
                    "this axis alias silently; widen to int64 or route "
                    "through the two-pass emit path")
        if prim in CALLBACK_PRIMS and not _benign_device_put(eqn):
            report.add(
                "jaxpr", "J_CALLBACK", target,
                f"host callback / device transfer primitive '{prim}' "
                "inside a jitted hot path — every call pays a host "
                "round-trip and blocks async dispatch")
        for aval in _avals(eqn):
            if aval.dtype == np.float64:
                report.add(
                    "jaxpr", "J_F64", target,
                    f"float64 value of shape {tuple(aval.shape)} in "
                    f"primitive '{prim}': the repo contract is "
                    "f32/int32 — check for a Python-float promotion")
                break  # one finding per eqn is enough

    for k, aval in enumerate(closed.out_avals):
        if getattr(aval, "weak_type", False):
            report.add(
                "jaxpr", "J_WEAK_OUT", target,
                f"output {k} is weak-typed {aval.dtype}: arithmetic on "
                "it can silently promote in callers; anchor the dtype "
                "with an explicit astype/asarray")
        if out_dtypes is not None and k < len(out_dtypes) \
                and out_dtypes[k] is not None \
                and np.dtype(aval.dtype) != np.dtype(out_dtypes[k]):
            report.add(
                "jaxpr", "J_DTYPE_CONTRACT", target,
                f"output {k} has dtype {np.dtype(aval.dtype).name} but "
                f"the declared contract is "
                f"{np.dtype(out_dtypes[k]).name}")


def _trace_checked(fn, args, kwargs, *, target: str, report: Report):
    """``make_jaxpr`` that converts trace-time int overflow to a finding.

    Once a dimension product crosses INT32_MAX, some index constants no
    longer *parse* as int32 — jit raises ``OverflowError`` before a
    jaxpr even exists.  That is the int32-width defect manifesting at
    trace time, so it is reported as ``J_INT32_INDEX`` rather than
    crashing the audit.
    """
    try:
        return jax.make_jaxpr(fn)(*args, **kwargs)
    except OverflowError as e:
        report.add(
            "jaxpr", "J_INT32_INDEX", target,
            "trace-time integer overflow while staging the jitted "
            f"computation ({str(e).splitlines()[0][:160]}) — an index "
            "constant at this scale no longer fits int32")
        return None


# ---------------------------------------------------------------------------
# probe → target shape scaling
# ---------------------------------------------------------------------------

def dim_expressions(n: int, m: int, cap: int) -> dict[str, "DimExpr"]:
    """Candidate symbolic meanings of a probe-trace dimension size."""
    return {
        "n": lambda s: s["n"],
        "m": lambda s: s["m"],
        "n+m": lambda s: s["n"] + s["m"],
        "n+m+1": lambda s: s["n"] + s["m"] + 1,
        "2n": lambda s: 2 * s["n"],
        "2m": lambda s: 2 * s["m"],
        "2(n+m)": lambda s: 2 * (s["n"] + s["m"]),
        "n*m": lambda s: s["n"] * s["m"],
        "cap": lambda s: s["cap"],
        "2cap": lambda s: 2 * s["cap"],
    }


def scale_dims(probe: dict[str, int], target: dict[str, int]):
    """``dim_map`` rewriting probe-trace dims to the target scale.

    Probe sizes are distinct primes, so every derived dimension of a
    captured argument (n, m, n+m, n+m+1, caps, products …) has exactly
    one candidate meaning; unmatched dims (small constants like 1, 2, d)
    pass through unchanged.  Returns ``(dim_map, unresolved)`` where
    ``unresolved`` collects dims > the largest probe size that matched
    nothing — a trace with unresolved large dims is audited at probe
    scale instead of silently mis-scaled.
    """
    exprs = dim_expressions(**probe)
    table: dict[int, int] = {}
    ambiguous: set[int] = set()
    for name, fn in exprs.items():
        pv, tv = fn(probe), fn(target)
        if pv in table and table[pv] != tv:
            ambiguous.add(pv)
        table[pv] = tv
    floor = max(probe.values())
    unresolved: set[int] = set()

    def dim_map(d: int) -> int:
        if d in ambiguous:
            unresolved.add(d)
            return d
        if d in table:
            return table[d]
        if d > floor:
            unresolved.add(d)
        return d

    return dim_map, unresolved


def audit_captured_call(call: CapturedCall, *, report: Report,
                        probe: dict[str, int] | None = None,
                        target_scale: dict[str, int] | None = None,
                        out_dtypes: tuple | None = None,
                        check_rank: bool = True) -> None:
    """Re-trace one captured executable abstractly and audit its jaxpr.

    With ``probe``/``target_scale`` the captured argument shapes are
    rewritten to the target problem size first, so int32-width findings
    reflect the matrix row's scale, not the tiny probe.
    """
    static_kw, traced_kw = call.split_kwargs()
    fn = functools.partial(call.fn, **static_kw) if static_kw else call.fn
    tgt = call.target

    dim_map = None
    if probe is not None and target_scale is not None:
        dim_map, unresolved = scale_dims(probe, target_scale)
        probe_dims = {d for a in jax.tree_util.tree_leaves(call.args)
                      if hasattr(a, "shape") for d in a.shape}
        # pre-scan: if any captured dim will not resolve, audit at
        # probe scale (never mis-scale silently)
        for d in probe_dims:
            dim_map(d)
        if unresolved:
            report.note_audit(
                "jaxpr", f"{tgt} (probe-scale only; unresolved dims "
                f"{sorted(unresolved)})")
            dim_map = None

    a_args = abstractify(call.args, dim_map)
    a_kw = abstractify(traced_kw, dim_map)

    closed = _trace_checked(fn, a_args, a_kw, target=tgt, report=report)
    if closed is None:
        report.note_audit("jaxpr", tgt)
        return
    audit_closed_jaxpr(closed, target=tgt, report=report,
                       out_dtypes=out_dtypes)

    if check_rank:
        try:
            with jax.numpy_rank_promotion("raise"):
                jax.eval_shape(fn, *a_args, **a_kw)
        except Exception as e:  # noqa: BLE001 — any trace error counts
            report.add(
                "jaxpr", "J_RANK_PROMOTION", tgt,
                "implicit rank promotion inside the jitted path "
                f"(trace under numpy_rank_promotion='raise' failed: "
                f"{str(e).splitlines()[0][:160]})")

    report.note_audit("jaxpr", tgt)


def audit_fn(fn, abstract_args, *, target: str, report: Report,
             static_kwargs: dict | None = None,
             out_dtypes: tuple | None = None,
             check_rank: bool = True) -> None:
    """Audit a bare function on explicit abstract args (no capture).

    Used for the module-level jits the pallas backend routes around the
    engine's ``_jitted`` (``kernels.ops``) and for corpus defects.
    """
    if static_kwargs:
        fn = functools.partial(fn, **static_kwargs)
    closed = _trace_checked(fn, abstract_args, {}, target=target,
                            report=report)
    if closed is None:
        report.note_audit("jaxpr", target)
        return
    audit_closed_jaxpr(closed, target=target, report=report,
                       out_dtypes=out_dtypes)
    if check_rank:
        try:
            with jax.numpy_rank_promotion("raise"):
                jax.eval_shape(fn, *abstract_args)
        except Exception as e:  # noqa: BLE001
            report.add(
                "jaxpr", "J_RANK_PROMOTION", target,
                "implicit rank promotion inside the jitted path "
                f"(trace under numpy_rank_promotion='raise' failed: "
                f"{str(e).splitlines()[0][:160]})")
    report.note_audit("jaxpr", target)
