"""Pass 2 — static audit of every ``pallas_call`` in ``kernels/``.

Works entirely from the ``KernelCapture`` records (grid, BlockSpecs,
scratch shapes, operand avals) taken while *tracing* the kernel wrappers
— the kernels never execute, so the audit covers the paper's N ≥ 1e6
regime in milliseconds:

``K_VMEM_BUDGET``
    True per-program VMEM footprint — every VMEM-resident input block
    (a ``BlockSpec`` without an explicit non-VMEM memory space; a spec
    with no ``block_shape`` pins the whole operand), every output
    block, and every VMEM scratch allocation — summed against the core
    budget.  This is the *real* number the BlockSpecs imply, not the
    route policy's model; the two are reconciled separately by
    ``audit_emit_route_parity``.

``K_OOB_INDEX_MAP``
    Every index map evaluated over the (possibly sampled) grid: each
    returned block index must keep ``(idx + 1) * block_dim`` inside the
    bound array for every dimension.

``K_WRITE_HAZARD``
    Two distinct grid steps mapping an output to the same block index —
    on TPU the grid is sequential so this is a silent last-write-wins,
    on other targets a data race.

``K_ROUTE_DRIFT``
    ``kernels.ops.emit_route_bytes`` (the byte model the route policy
    decides on) re-derived from the captured BlockSpecs/scratch of the
    *real* emit kernels; the model must bracket the derived bytes to
    within lane-padding slack for both regimes.
"""
from __future__ import annotations

import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from .capture import KernelCapture, trace_kernel
from .report import Report

VMEM_BUDGET = 16 << 20          # v5e-class core VMEM
GRID_SAMPLE_CAP = 4096          # full enumeration below this many steps


# ---------------------------------------------------------------------------
# BlockSpec / scratch byte accounting
# ---------------------------------------------------------------------------

def _memory_space_name(obj) -> str:
    ms = getattr(obj, "memory_space", None)
    return "" if ms is None else str(ms).lower()


def _spec_in_vmem(spec) -> bool:
    name = _memory_space_name(spec)
    if not name:                 # default memory space is VMEM
        return True
    return "vmem" in name


def _nbytes(shape, dtype) -> int:
    return int(np.prod([int(d) for d in shape], initial=1)
               * np.dtype(dtype).itemsize)


def block_bytes(spec, aval) -> int:
    """Bytes one grid step keeps live in VMEM for this operand."""
    if not _spec_in_vmem(spec):
        return 0
    bs = getattr(spec, "block_shape", None)
    shape = aval.shape if bs is None else tuple(
        int(b) for b in bs)
    return _nbytes(shape, aval.dtype)


def scratch_bytes(ref) -> int:
    name = _memory_space_name(ref)
    if "vmem" not in name:       # SMEM / semaphores don't charge VMEM
        return 0
    return _nbytes(ref.shape, ref.dtype)


def vmem_footprint(cap: KernelCapture) -> int:
    """Static per-program VMEM bytes implied by the captured specs."""
    nsp = cap.num_scalar_prefetch
    blocked_ops = cap.operands[nsp:]
    total = 0
    for spec, aval in zip(cap.in_specs, blocked_ops):
        total += block_bytes(spec, aval)
    for spec, aval in zip(cap.out_specs, cap.out_shapes):
        total += block_bytes(spec, aval)
    for ref in cap.scratch_shapes:
        total += scratch_bytes(ref)
    return total


# ---------------------------------------------------------------------------
# grid enumeration (sampled beyond GRID_SAMPLE_CAP steps)
# ---------------------------------------------------------------------------

def grid_points(grid: tuple, cap: int = GRID_SAMPLE_CAP):
    """All grid coordinates, or a boundary-heavy strided sample.

    Sampling always includes every axis's endpoints (index-map bugs
    live at the edges), so an out-of-bounds final block is never
    missed; interior coverage is strided to keep the product under
    ``cap``.
    """
    dims = [int(g) for g in grid]
    if not dims:
        return [()]
    total = int(np.prod(dims))
    if total <= cap:
        return list(itertools.product(*[range(g) for g in dims]))
    per_axis = max(2, int(cap ** (1.0 / len(dims))))
    axes = []
    for g in dims:
        if g <= per_axis:
            axes.append(list(range(g)))
            continue
        step = max(1, (g - 1) // (per_axis - 1))
        picks = sorted({0, g - 1, *range(0, g, step)})
        axes.append(picks)
    return list(itertools.product(*axes))


def _eval_index_map(spec, coords, scalar_args):
    fn = getattr(spec, "index_map", None)
    if fn is None:
        return None
    idx = fn(*coords, *scalar_args)
    if not isinstance(idx, tuple):
        idx = (idx,)
    return tuple(int(v) for v in idx)


def _check_bounds(spec, aval, idx, *, where: str, coords, target: str,
                  report: Report) -> None:
    bs = getattr(spec, "block_shape", None)
    if bs is None or idx is None:
        return
    for k, (bi, bd, dim) in enumerate(zip(idx, bs, aval.shape)):
        if bi < 0 or (bi + 1) * int(bd) > int(dim):
            report.add(
                "kernel", "K_OOB_INDEX_MAP", target,
                f"{where} index map at grid{tuple(coords)} returns block "
                f"{idx}: axis {k} spans "
                f"[{bi * int(bd)}, {(bi + 1) * int(bd)}) outside the "
                f"array dim {int(dim)}")
            return


def audit_kernel_capture(cap: KernelCapture, *, report: Report,
                         budget: int = VMEM_BUDGET,
                         grid_cap: int = GRID_SAMPLE_CAP) -> None:
    """Footprint + bounds + hazard checks for one captured kernel."""
    target = cap.target

    used = vmem_footprint(cap)
    if used > budget:
        report.add(
            "kernel", "K_VMEM_BUDGET", target,
            f"static VMEM footprint {used} bytes "
            f"({used / (1 << 20):.1f} MiB) exceeds the "
            f"{budget >> 20} MiB core budget — grid {cap.grid}, "
            f"{len(cap.in_specs)} in / {len(cap.out_specs)} out specs")

    nsp = cap.num_scalar_prefetch
    # index maps may consult scalar-prefetch operands; hand them zeros
    # of the right shape (repo maps only use the grid coordinates).
    scalar_args = [np.zeros(a.shape, np.dtype(a.dtype))
                   for a in cap.operands[:nsp]]
    blocked_ops = cap.operands[nsp:]
    pts = grid_points(cap.grid, grid_cap)
    sampled = len(pts) < int(np.prod([int(g) for g in cap.grid],
                                     initial=1))

    seen_out: dict[tuple, tuple] = {}
    hazards = 0
    for coords in pts:
        for spec, aval in zip(cap.in_specs, blocked_ops):
            idx = _eval_index_map(spec, coords, scalar_args)
            _check_bounds(spec, aval, idx, where="input", coords=coords,
                          target=target, report=report)
        out_key = []
        for spec, aval in zip(cap.out_specs, cap.out_shapes):
            idx = _eval_index_map(spec, coords, scalar_args)
            _check_bounds(spec, aval, idx, where="output", coords=coords,
                          target=target, report=report)
            out_key.append(idx)
        key = tuple(out_key)
        if key in seen_out and hazards < 3:
            hazards += 1
            report.add(
                "kernel", "K_WRITE_HAZARD", target,
                f"grid steps {seen_out[key]} and {tuple(coords)} both "
                f"write output block(s) {key}: sequential "
                "last-write-wins on TPU, a data race elsewhere")
        seen_out.setdefault(key, tuple(coords))

    note = target + (" (sampled grid)" if sampled else "")
    report.note_audit("kernel", note)


# ---------------------------------------------------------------------------
# route-model parity for the two emit kernels
# ---------------------------------------------------------------------------

def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def derived_table_bytes(cap: KernelCapture) -> int:
    """Route-relevant VMEM bytes from the captured emit-kernel specs.

    Counts what the route *policy* models: VMEM-resident input tables
    plus VMEM scratch.  Output blocks and the scalar-prefetch operand
    are excluded (both regimes pay the same output block, and the
    policy models table residency only); ANY-space operands stream from
    HBM and charge their window via the scratch term.
    """
    nsp = cap.num_scalar_prefetch
    total = 0
    for spec, aval in zip(cap.in_specs, cap.operands[nsp:]):
        total += block_bytes(spec, aval)
    for ref in cap.scratch_shapes:
        total += scratch_bytes(ref)
    return total


def audit_emit_route_parity(report: Report, *, n: int = 4000,
                            m: int = 3000, max_pairs: int = 8192,
                            block: int | None = None) -> None:
    """Assert ``emit_route_bytes`` matches the real kernels' specs.

    All three emit kernels are traced abstractly at ``(n, m,
    max_pairs)``; the policy's modeled bytes must bracket the
    spec-derived bytes to within lane-padding slack (each table is
    padded up to the next 128 lanes, int32; the csr route's footprint
    is all scratch, so its model must match exactly).  Drift in either
    direction — a kernel change not reflected in the model, or a model
    change not reflected in the kernels — is ``K_ROUTE_DRIFT``.
    """
    from ..kernels import emit as emit_kernel
    from ..kernels import ops

    block = emit_kernel.DEF_BLOCK if block is None else block
    model = ops.emit_route_bytes(n, m, block=block)
    e = n + m
    lane = 128 * np.dtype(np.int32).itemsize
    tables = dict(
        offs=_i32(e + 1), counts=_i32(e), starts=_i32(e),
        perm_s=_i32(n), perm_u=_i32(m))

    for route, fn in (("resident", emit_kernel.twopass_emit),
                      ("streaming", emit_kernel.twopass_emit_streaming)):
        target = f"emit_route_parity:{route}"
        wrapped = functools.partial(fn, n=n, m=m, max_pairs=max_pairs,
                                    block=block)
        caps = trace_kernel(wrapped, tables["offs"], tables["counts"],
                            tables["starts"], tables["perm_s"],
                            tables["perm_u"])
        if len(caps) != 1:
            report.add(
                "kernel", "K_ROUTE_DRIFT", target,
                f"expected exactly one pallas_call while tracing the "
                f"{route} emit kernel, captured {len(caps)}")
            continue
        derived = derived_table_bytes(caps[0])
        modeled = model[route]
        # slack: one lane-round-up per VMEM-charged table
        n_tables = 5 if route == "resident" else 2
        slack = n_tables * lane
        if not modeled <= derived <= modeled + slack:
            report.add(
                "kernel", "K_ROUTE_DRIFT", target,
                f"emit_route_bytes models {modeled} bytes for the "
                f"{route} route but the captured BlockSpecs/scratch "
                f"imply {derived} (allowed [{modeled}, "
                f"{modeled + slack}]) at (n={n}, m={m}, "
                f"max_pairs={max_pairs}, block={block}) — the policy "
                "and the kernels have drifted apart")
        report.note_audit("kernel", target)

    # csr decode: different signature (packed table + padded perms +
    # a dynamic window start) and an all-scratch footprint — the model
    # must match the captured scratch exactly, no table slack.
    target = "emit_route_parity:csr"
    bl = emit_kernel.lane_pad(block)
    win = emit_kernel.stream_window(bl)
    e_pad = e + max((-e) % 128, win - e)
    wrapped = functools.partial(emit_kernel.csr_decode_window, n=n, m=m,
                                nslots=max_pairs, block=block)
    caps = trace_kernel(wrapped, _i32(8, e_pad),
                        _i32(1, emit_kernel.lane_pad(n + bl)),
                        _i32(1, emit_kernel.lane_pad(m + bl)), _i32())
    if len(caps) != 1:
        report.add(
            "kernel", "K_ROUTE_DRIFT", target,
            f"expected exactly one pallas_call while tracing the csr "
            f"decode kernel, captured {len(caps)}")
        return
    derived = derived_table_bytes(caps[0])
    modeled = model["csr"]
    if derived != modeled:
        report.add(
            "kernel", "K_ROUTE_DRIFT", target,
            f"emit_route_bytes models {modeled} bytes for the csr "
            f"route but the captured scratch implies {derived} at "
            f"(n={n}, m={m}, max_pairs={max_pairs}, block={block}) — "
            "the policy and the kernels have drifted apart")
    report.note_audit("kernel", target)
