"""Findings and the JSON report shared by every auditor pass.

A *finding* is one statically-detected defect: the pass that found it,
a stable machine-readable code (tests and the seeded-defect corpus key
on these), the audited target, and a human-readable message.  The
*report* accumulates findings plus a per-pass log of everything that was
audited — so "no findings" is distinguishable from "nothing ran".

Codes (stable API — the corpus and CI key on them):

``jaxpr`` pass
    ``J_INT32_INDEX``     int32 index space wider than INT32_MAX
    ``J_F64``             float64 value in a traced hot path
    ``J_WEAK_OUT``        weak-typed output (promotion hazard for callers)
    ``J_DTYPE_CONTRACT``  output dtype differs from the declared contract
    ``J_RANK_PROMOTION``  implicit rank promotion inside a jitted path
    ``J_CALLBACK``        host callback / device transfer inside a jitted
                          hot path

``kernel`` pass
    ``K_VMEM_BUDGET``     static VMEM footprint exceeds the core budget
    ``K_OOB_INDEX_MAP``   a BlockSpec index map leaves the array bounds
    ``K_WRITE_HAZARD``    two grid steps write the same output tile
    ``K_ROUTE_DRIFT``     ``emit_route_bytes`` disagrees with the real
                          BlockSpecs/scratch of the emit kernels
    ``K_NO_CAPTURE``      a kernel matrix entry traced without any
                          ``pallas_call`` — the audit lost coverage

``retrace`` pass
    ``R_GROW_BOUND``      a grow-capacity resolver exceeds the O(lg K)
                          distinct-trace-shape bound
    ``R_STEADY_STATE``    a second identical plan call retraced (the
                          live ``no_retrace`` probe fired)

``lint`` pass
    ``L_DEPRECATED``      call of a deprecated shim in src/ or benchmarks/
    ``L_EMPTY_GUARD``     ``pallas_call`` wrapper taking ``max_pairs``
                          without the ``max_pairs == 0`` short-circuit
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

PASSES = ("jaxpr", "kernel", "retrace", "lint")


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_name: str     # one of PASSES
    code: str          # stable machine-readable defect code (above)
    target: str        # what was audited (matrix row, kernel, file:line)
    message: str       # human-readable detail
    severity: str = "error"   # "error" gates CI; "warning" is advisory

    def __str__(self) -> str:
        return (f"[{self.pass_name}/{self.code}] {self.target}: "
                f"{self.message}")


class Report:
    """Accumulated findings + audit coverage, serializable to JSON."""

    def __init__(self) -> None:
        self.findings: list[Finding] = []
        self.audited: dict[str, list[str]] = {p: [] for p in PASSES}

    def add(self, pass_name: str, code: str, target: str, message: str,
            severity: str = "error") -> Finding:
        f = Finding(pass_name, code, target, message, severity)
        self.findings.append(f)
        return f

    def note_audit(self, pass_name: str, target: str) -> None:
        self.audited.setdefault(pass_name, []).append(target)

    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def ok(self) -> bool:
        return not self.errors()

    def findings_for(self, pass_name: str | None = None,
                     target_substr: str | None = None) -> list[Finding]:
        out = self.findings
        if pass_name is not None:
            out = [f for f in out if f.pass_name == pass_name]
        if target_substr is not None:
            out = [f for f in out if target_substr in f.target]
        return out

    def codes(self) -> set[str]:
        return {f.code for f in self.findings}

    def to_dict(self) -> dict:
        return {
            "ok": self.ok(),
            "n_findings": len(self.findings),
            "n_errors": len(self.errors()),
            "audited": {p: sorted(t) for p, t in self.audited.items()},
            "findings": [dataclasses.asdict(f) for f in self.findings],
        }

    def write_json(self, path: str) -> None:
        Path(path).write_text(
            json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n")

    def summary(self) -> str:
        lines = ["static analysis summary:"]
        for p in PASSES:
            n_aud = len(self.audited.get(p, []))
            n_find = len(self.findings_for(p))
            lines.append(f"  {p:8s} audited {n_aud:4d} target(s), "
                         f"{n_find} finding(s)")
        for f in self.findings:
            lines.append(f"  {f}")
        lines.append("RESULT: " + ("OK" if self.ok() else "FINDINGS"))
        return "\n".join(lines)
