"""Seeded-defect corpus runner — proves the auditor actually detects.

A corpus module (``tests/analysis_corpus/corpus_*.py``) defines

    CASES = [
        {"name": "...",            # unique within the corpus
         "pass_name": "jaxpr",     # which auditor pass must fire
         "code": "J_INT32_INDEX",  # the finding code it must raise
         "audit": fn},             # fn(report, target) runs the audit
        ...
    ]

Each case is executed against a fresh isolated ``Report``; the case
*passes* when the expected finding code appears for its pass.  A seeded
defect the auditor fails to flag is a regression in the auditor itself —
the runner reports it and the CLI exits non-zero.  Corpus findings never
pollute the repo report: they are expected.
"""
from __future__ import annotations

import dataclasses
import importlib.util
import sys
from pathlib import Path

from .report import Report


@dataclasses.dataclass
class CaseResult:
    module: str
    name: str
    pass_name: str
    code: str
    detected: bool
    got_codes: tuple[str, ...]
    error: str | None = None

    @property
    def ok(self) -> bool:
        return self.detected and self.error is None


def load_corpus_modules(corpus_dir: str | Path):
    corpus_dir = Path(corpus_dir)
    mods = []
    for path in sorted(corpus_dir.glob("corpus_*.py")):
        modname = f"_repro_analysis_corpus_{path.stem}"
        spec = importlib.util.spec_from_file_location(modname, path)
        mod = importlib.util.module_from_spec(spec)
        sys.modules[modname] = mod
        spec.loader.exec_module(mod)
        mods.append((path.stem, mod))
    return mods


def run_corpus(corpus_dir: str | Path) -> list[CaseResult]:
    """Run every seeded defect; each must be flagged with its code."""
    results: list[CaseResult] = []
    for stem, mod in load_corpus_modules(corpus_dir):
        for case in getattr(mod, "CASES", []):
            name = case["name"]
            target = f"corpus:{stem}:{name}"
            sub = Report()
            error = None
            try:
                case["audit"](sub, target)
            except Exception as e:  # noqa: BLE001 — auditor crash = fail
                error = f"{type(e).__name__}: {e}"
            got = tuple(sorted(
                f.code for f in sub.findings_for(case["pass_name"])))
            detected = case["code"] in got
            results.append(CaseResult(
                module=stem, name=name, pass_name=case["pass_name"],
                code=case["code"], detected=detected, got_codes=got,
                error=error))
    return results


def corpus_summary(results: list[CaseResult]) -> str:
    lines = [f"corpus: {len(results)} seeded defect(s)"]
    for r in results:
        status = "DETECTED" if r.ok else "MISSED"
        extra = f" [{r.error}]" if r.error else ""
        got = ",".join(r.got_codes) or "-"
        lines.append(f"  {status:8s} {r.module}:{r.name} "
                     f"expect {r.code} got {got}{extra}")
    missed = [r for r in results if not r.ok]
    lines.append(f"corpus RESULT: "
                 + ("OK" if results and not missed
                    else f"{len(missed)} MISSED" if results
                    else "EMPTY"))
    return "\n".join(lines)


def corpus_to_dict(results: list[CaseResult]) -> dict:
    return {
        "n_cases": len(results),
        "n_missed": sum(not r.ok for r in results),
        "cases": [dataclasses.asdict(r) for r in results],
    }
