"""Static plan & kernel auditor — verification without execution.

``python -m repro.analysis`` runs four passes over the repo (see
``docs/API.md`` §Static analysis):

1. **jaxpr audit** — every engine matrix row's executables re-traced
   abstractly at production scale (int32 index width, f64/weak-type
   promotion, rank promotion, host callbacks).
2. **kernel audit** — every ``pallas_call`` checked statically (VMEM
   footprint, index-map bounds, write-write hazards) plus the
   emit-route byte-model parity assertion.
3. **retrace guard** — ``no_retrace`` (the enforceable steady-state
   context manager) and the grow-capacity O(lg K) bound.
4. **repo AST lint** — deprecated-shim ban and the ``max_pairs == 0``
   kernel-wrapper contract.

The seeded-defect corpus under ``tests/analysis_corpus/`` keeps the
auditor honest: every corpus entry must be flagged.
"""
from .capture import (CapturedCall, KernelCapture, abstractify,
                      capture_pallas_calls, capture_plan_executables,
                      trace_kernel)
from .corpus import run_corpus
from .jaxpr_audit import audit_captured_call, audit_closed_jaxpr, audit_fn
from .kernel_audit import (audit_emit_route_parity, audit_kernel_capture,
                           derived_table_bytes, vmem_footprint)
from .lint import lint_paths, lint_source
from .matrix import (PROBE, TARGETS, audit_kernel_matrix,
                     audit_plan_matrix, audit_retrace_matrix, run_all)
from .report import Finding, Report
from .retrace import (RetraceError, audit_grow_bound, grow_bound,
                      no_retrace)

__all__ = [
    "CapturedCall", "KernelCapture", "Finding", "Report",
    "RetraceError", "PROBE", "TARGETS",
    "abstractify", "audit_captured_call", "audit_closed_jaxpr",
    "audit_emit_route_parity", "audit_fn", "audit_grow_bound",
    "audit_kernel_capture", "audit_kernel_matrix", "audit_plan_matrix",
    "audit_retrace_matrix", "capture_pallas_calls",
    "capture_plan_executables", "derived_table_bytes", "grow_bound",
    "lint_paths", "lint_source", "no_retrace", "run_all", "run_corpus",
    "trace_kernel", "vmem_footprint",
]
