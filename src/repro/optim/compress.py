"""int8 stochastic-rounding gradient compression.

Distributed-optimization trick for the slow cross-pod hop: gradients are
quantized to int8 with a per-tensor scale before the inter-pod
all-reduce and dequantized after, cutting inter-pod bytes 4× (fp32) /
2× (bf16).  Stochastic rounding keeps the quantizer unbiased
(E[q] = x), so SGD-style convergence guarantees survive; the intra-pod
reduction stays full precision.

Used by ``launch.train`` when ``--compress-grads`` is set: grads are
psum'd over the in-pod axes in fp32, compressed, psum'd over the 'pod'
axis in int8 (values summed as int32 to avoid saturation), then
dequantized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x, key):
    """Returns (q int8, scale f32). Unbiased via stochastic rounding."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    y = xf / scale
    lo = jnp.floor(y)
    frac = y - lo
    rnd = jax.random.uniform(key, x.shape)
    q = lo + (rnd < frac)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, key):
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    qs, scales = zip(*(compress_int8(l, k) for l, k in zip(leaves, keys)))
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales))


def decompress_tree(qs, scales):
    return jax.tree.map(decompress_int8, qs, scales)
