"""Optimizer substrate (no external deps): AdamW + schedule + clipping +
optional int8 gradient compression for cross-pod all-reduce."""
from .adamw import (AdamWConfig, adamw_init, adamw_update,
                    cosine_schedule, global_norm, clip_by_global_norm)
from .compress import compress_int8, decompress_int8

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "clip_by_global_norm", "compress_int8",
           "decompress_int8"]
