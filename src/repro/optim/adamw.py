"""AdamW with decoupled weight decay, cosine LR schedule, global-norm
clipping.  Pure pytree-in/pytree-out; state is {m, v, step} mirroring the
param tree (fp32), so it shards identically to the parameters (ZeRO-style
when params are FSDP-sharded)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else \
        jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree: PyTree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: PyTree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale
                                   ).astype(l.dtype), tree), norm


def adamw_init(params: PyTree) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params: PyTree, grads: PyTree, state: PyTree,
                 cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        p2 = pf - lr * (delta + cfg.weight_decay * pf)
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    state2 = {"m": new_m, "v": new_v, "step": step}
    return new_p, state2, {"grad_norm": gnorm, "lr": lr}
