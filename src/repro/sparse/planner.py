"""DDM-planned block-sparse attention layout.

This is the paper's service applied inside the LM framework: each query
block *subscribes* to the key range it may attend to (causal sliding
window + global sink prefix), each KV block is an *update region*; the
block-level attention layout is exactly the set of overlapping
(subscription, update) pairs — computed by ``repro.core`` matching, the
same code path as the HLA pub/sub benchmarks.

Outputs:
  * ``block_bitmask``  — (nq, nkv) bool, consumed by tests/reference;
  * ``block_windows``  — per-q-block contiguous [start, end) token ranges
    (+ sink prefix end), consumed by the Pallas kernel and by the decode
    cache read;
the two are provably consistent (tests assert bitmask == windows).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..core import (MatchSpec, Regions, block_mask,
                    build_plan)


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    seq_len: int
    block_q: int
    block_kv: int
    window: int
    sink_blocks: int

    @property
    def nq(self) -> int:
        return -(-self.seq_len // self.block_q)

    @property
    def nkv(self) -> int:
        return -(-self.seq_len // self.block_kv)

    @property
    def sink_end(self) -> int:
        return self.sink_blocks * self.block_kv


def _q_subscriptions(plan: BlockPlan) -> Regions:
    """Query block i subscribes to keys [max(0, end_i - window), end_i)."""
    i = np.arange(plan.nq, dtype=np.float32)
    end = np.minimum((i + 1) * plan.block_q, plan.seq_len)
    start = np.maximum(end - plan.window, 0.0)
    return Regions(jnp.asarray(start)[:, None], jnp.asarray(end)[:, None])


def _kv_updates(plan: BlockPlan) -> Regions:
    j = np.arange(plan.nkv, dtype=np.float32)
    lo = j * plan.block_kv
    hi = np.minimum((j + 1) * plan.block_kv, plan.seq_len)
    return Regions(jnp.asarray(lo)[:, None], jnp.asarray(hi)[:, None])


def block_bitmask(plan: BlockPlan) -> np.ndarray:
    """(nq, nkv) bool via DDM interval matching + sink columns."""
    S = _q_subscriptions(plan)
    U = _kv_updates(plan)
    mask = np.array(block_mask(S.lo[:, 0], S.hi[:, 0],
                               U.lo[:, 0], U.hi[:, 0]))
    mask[:, : plan.sink_blocks] = True
    # causality at block granularity: kv block start < q block end
    j_lo = np.arange(plan.nkv) * plan.block_kv
    i_end = np.minimum((np.arange(plan.nq) + 1) * plan.block_q,
                       plan.seq_len)
    mask &= j_lo[None, :] < i_end[:, None]
    return mask


def block_windows(plan: BlockPlan):
    """Per-q-block contiguous kv token ranges (starts, ends) int32 (nq,).

    Derived from the DDM pair enumeration (not re-derived arithmetic):
    enumerate (q-block, kv-block) matches with an engine ``MatchPlan``
    (exact-capacity SBM), reduce each q row to its [min, max] matched kv
    block.  The sink
    prefix is carried separately (``plan.sink_end``).
    """
    S = _q_subscriptions(plan)
    U = _kv_updates(plan)
    mplan = build_plan(MatchSpec(algo="sbm", capacity="exact"),
                       S.n, U.n, S.d)
    pairs, count = mplan.pairs(S, U)
    pairs = np.asarray(pairs)
    pairs = pairs[pairs[:, 0] >= 0]
    starts = np.full(plan.nq, np.iinfo(np.int32).max, np.int64)
    ends = np.zeros(plan.nq, np.int64)
    np.minimum.at(starts, pairs[:, 0], pairs[:, 1] * plan.block_kv)
    np.maximum.at(ends, pairs[:, 0], (pairs[:, 1] + 1) * plan.block_kv)
    # causal clip to the q block's own end, and clip to seq_len
    i_end = np.minimum((np.arange(plan.nq) + 1) * plan.block_q,
                       plan.seq_len)
    ends = np.minimum(np.minimum(ends, plan.seq_len), i_end)
    starts = np.minimum(starts, ends)
    return starts.astype(np.int32), ends.astype(np.int32)


def decode_window(pos: int, plan: BlockPlan) -> tuple[int, int]:
    """Decode-time read range for a query at absolute position ``pos``:
    [max(sink_end, pos+1-window), pos+1) plus the [0, sink_end) prefix."""
    end = pos + 1
    start = max(end - plan.window, 0)
    return start, end
