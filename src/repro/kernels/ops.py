"""Jitted public wrappers around the Pallas kernels.

These handle padding to tile multiples (with non-matching sentinel
regions / zero-contribution sentinel endpoints), call the kernels, and
trim back — so callers never see tile-size constraints.  ``interpret=True``
(default off) runs the kernel bodies in Python on CPU; ops are used with
interpret mode in tests and benchmarks on this host, and compile to
Mosaic on real TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.pairs import PairsResult
from ..core.regions import Regions
from ..core.sbm import _endpoint_stream, _hsbm_phase1, _twopass_phase1
from . import bfm as bfm_kernel
from . import emit as emit_kernel
from . import sbm_sweep as sweep_kernel


def _pad_regions(lo, hi, mult: int):
    n = lo.shape[0]
    pad = (-n) % mult
    if pad:
        lo = jnp.pad(lo, ((0, pad), (0, 0)), constant_values=jnp.inf)
        hi = jnp.pad(hi, ((0, pad), (0, 0)), constant_values=-jnp.inf)
    return lo, hi


@functools.partial(jax.jit, static_argnames=("ts", "tu", "interpret"))
def _tile_counts(s_lo, s_hi, u_lo, u_hi, ts, tu, interpret):
    s_lo, s_hi = _pad_regions(s_lo, s_hi, ts)
    u_lo, u_hi = _pad_regions(u_lo, u_hi, tu)
    return bfm_kernel.bfm_tile_counts(s_lo, s_hi, u_lo, u_hi,
                                      ts=ts, tu=tu, interpret=interpret)


def bfm_count_pallas(S: Regions, U: Regions, *, ts: int = 256,
                     tu: int = 256, interpret: bool = False) -> int:
    """Total K via the tiled Pallas BFM kernel (any d, any n/m)."""
    if S.n == 0 or U.n == 0:
        return 0
    tiles = _tile_counts(S.lo, S.hi, U.lo, U.hi, ts, tu, interpret)
    return int(np.sum(np.asarray(tiles), dtype=np.int64))


@functools.partial(jax.jit, static_argnames=("ts", "tu", "interpret"))
def _mask_padded(s_lo, s_hi, u_lo, u_hi, ts, tu, interpret):
    s_lo, s_hi = _pad_regions(s_lo, s_hi, ts)
    u_lo, u_hi = _pad_regions(u_lo, u_hi, tu)
    return bfm_kernel.bfm_mask(s_lo, s_hi, u_lo, u_hi,
                               ts=ts, tu=tu, interpret=interpret)


def bfm_mask_pallas(S: Regions, U: Regions, *, ts: int = 256,
                    tu: int = 256, interpret: bool = False):
    """(n, m) bool overlap mask via the tiled Pallas kernel."""
    if S.n == 0 or U.n == 0:
        return jnp.zeros((S.n, U.n), jnp.bool_)
    full = _mask_padded(S.lo, S.hi, U.lo, U.hi, ts, tu, interpret)
    return full[: S.n, : U.n]


@functools.partial(jax.jit, static_argnames=("max_pairs",))
def _compact_mask_pairs(mask, max_pairs):
    m = mask.shape[1]
    flat = jnp.nonzero(mask.ravel(), size=max_pairs, fill_value=-1)[0]
    s_idx = jnp.where(flat >= 0, flat // m, -1).astype(jnp.int32)
    u_idx = jnp.where(flat >= 0, flat % m, -1).astype(jnp.int32)
    return jnp.stack([s_idx, u_idx], axis=1), jnp.sum(mask, dtype=jnp.int32)


def bfm_pairs_pallas(S: Regions, U: Regions, max_pairs: int, *,
                     ts: int = 256, tu: int = 256,
                     interpret: bool = False):
    """Enumerate overlapping pairs from the Pallas tile mask (any d).

    Returns ``(pairs int32 (max_pairs, 2) −1-padded, exact count)``.
    The mask tiles come from the Pallas kernel; compaction is an XLA
    nonzero for now — a fused Pallas two-pass emit kernel is a ROADMAP
    open item and slots in here without changing this signature.
    """
    if S.n == 0 or U.n == 0:
        return jnp.full((max_pairs, 2), -1, jnp.int32), 0
    if S.n * U.n > np.iinfo(np.int32).max:
        # the mask compaction ravels to flat int32 indices in [0, n*m);
        # past INT32_MAX they alias silently.  The static auditor
        # (repro.analysis) flags this bound from the jaxpr; here it is
        # enforced dynamically with an actionable message.
        raise ValueError(
            f"bfm pair enumeration ravels an (n, m) = ({S.n}, {U.n}) "
            f"mask to flat int32 indices; n*m = {S.n * U.n} exceeds "
            f"INT32_MAX = {np.iinfo(np.int32).max}. Use the sbm/itm "
            "two-pass emit path at this scale (MatchSpec(algo='sbm')).")
    mask = bfm_mask_pallas(S, U, ts=ts, tu=tu, interpret=interpret)
    pairs, count = _compact_mask_pairs(mask, max_pairs)
    return pairs, int(count)


@functools.partial(jax.jit, static_argnames=("max_pairs",))
def _twopass_tables(s_lo, s_hi, u_lo, u_hi, max_pairs):
    perm_s, perm_u, starts, counts, offs, cnt_a, cnt_b = _twopass_phase1(
        s_lo, s_hi, u_lo, u_hi, max_pairs)
    return perm_s, perm_u, starts, counts, offs, cnt_a, cnt_b


# Emit-route policy.  The resident emit kernel keeps all five lookup
# tables VMEM-resident (shared by every grid step); past the byte budget
# they cannot fit beside the output block on a real TPU core.  The
# streaming kernel DMAs the offset/count/start tables per tile and only
# keeps the two sort permutations resident, reaching ~4x further.  The
# csr route keeps NOTHING resident — tables and permutation runs both
# stream per tile, so its footprint is constant in n+m and the route's
# reach is unbounded; it returns a lazy CSRPairs view instead of a
# dense buffer, so the d>1 verify path (which needs dense candidates)
# falls through to the bit-identical XLA pass 2 instead.  Tests
# monkeypatch the budget to exercise every route at small sizes.
_EMIT_VMEM_TABLE_BUDGET = 8 << 20
EMIT_ROUTES = ("auto", "resident", "streaming", "csr", "xla")

# last route taken by twopass_pairs_pallas (None before any call /
# after an empty-set short-circuit) — lets tests and benchmarks prove
# which kernel actually ran rather than trusting the policy.
_LAST_EMIT_ROUTE: str | None = None


def last_emit_route() -> str | None:
    return _LAST_EMIT_ROUTE


def emit_route_bytes(n: int, m: int, *, block: int = emit_kernel.DEF_BLOCK
                     ) -> dict:
    """VMEM byte math behind the route policy (int32 words x 4).

    ``resident``: offsets (n+m+1) + counts + starts (n+m each) + the two
    permutations (n + m) all live in VMEM for the whole grid.
    ``streaming``: only the permutations are resident; the packed
    emitter table streams through a double-buffered 2 x (8, block+256)
    window.
    ``csr``: nothing is resident — one (8, win) table window plus one
    (1, 2·block) run-landing line per tile, both DMA-fed.  Constant in
    n + m, so the csr need never exceeds any budget the other kernels
    fit (the decode kernel's reach is bounded by int32 slot ids, not
    by VMEM).
    """
    e = n + m
    bl = emit_kernel.lane_pad(block)
    win = emit_kernel.stream_window(bl)
    return {
        "resident": 4 * (3 * (e + 1) + e),
        "streaming": 4 * e + 2 * 8 * win * 4,
        "csr": 4 * (8 * win + 2 * bl),
    }


def choose_emit_route(n: int, m: int, *,
                      block: int = emit_kernel.DEF_BLOCK,
                      budget: int | None = None,
                      dense_only: bool = False) -> str:
    """Smallest-footprint emit route whose VMEM need fits ``budget``.

    Pure and deterministic: ``resident`` while all five tables fit,
    then ``streaming`` while the permutations alone fit, then ``csr``
    (constant footprint, lazy decode view), else ``xla``.
    ``dense_only=True`` skips ``csr`` for callers that need a dense
    candidate buffer (the engine's d > 1 verify path).  ``budget=None``
    reads the module default (monkeypatchable).
    """
    budget = _EMIT_VMEM_TABLE_BUDGET if budget is None else budget
    need = emit_route_bytes(n, m, block=block)
    if need["resident"] <= budget:
        return "resident"
    if need["streaming"] <= budget:
        return "streaming"
    if not dense_only and need["csr"] <= budget:
        return "csr"
    return "xla"


class CSRPairs(PairsResult):
    """Lazy ``PairsResult`` over the CSR emit form — decode on demand.

    Same contract as the dense ``DensePairs`` the other routes wrap,
    but holds only pass 1's compressed tables on device (packed
    compacted emitter table + the two padded sort permutations:
    O(n+m) words, never O(K)).  ``decode(start, stop)`` materializes
    just that slot window through the constant-VMEM
    ``kernels.emit.csr_decode_window`` kernel — bit-identical to the
    dense buffer's same slice, including the −1 pad past the true
    count.  Windows are padded up to a power of two before the kernel
    call, so sweeping any cap costs O(lg cap) distinct compiles total;
    the window *offset* is a traced scalar and never retraces.

    ``np.asarray(view)`` / ``to_dense()`` materialize the full dense
    buffer (inherited from ``PairsResult``, assembled window-by-window
    on host for ``__array__``), so every dense consumer —
    ``pairs_to_set``, ``validate_pairs``, the parity suites — works
    unchanged; large-K callers should iterate ``windows()`` instead
    and never hold the O(K) buffer.
    """

    def __init__(self, tab, perm_s_pad, perm_u_pad, *, n: int, m: int,
                 cap: int, count: int,
                 block: int = emit_kernel.DEF_BLOCK,
                 interpret: bool = False):
        self.tab = tab
        self.perm_s_pad = perm_s_pad
        self.perm_u_pad = perm_u_pad
        self.n = int(n)
        self.m = int(m)
        self.cap = int(cap)
        self.count = int(count)
        self.block = int(block)
        self.interpret = bool(interpret)

    @classmethod
    def empty(cls, cap: int, *, n: int = 0, m: int = 0,
              block: int = emit_kernel.DEF_BLOCK,
              interpret: bool = False) -> "CSRPairs":
        """All-pad view (empty region sets / zero capacity)."""
        return cls(None, None, None, n=n, m=m, cap=cap, count=0,
                   block=block, interpret=interpret)

    @property
    def nbytes(self) -> int:
        """Device bytes actually held (the compressed CSR form)."""
        if self.tab is None:
            return 0
        return 4 * int(self.tab.size + self.perm_s_pad.size
                       + self.perm_u_pad.size)

    def decode(self, start: int = 0, stop: int | None = None):
        """Dense int32 (stop−start, 2) slice of slots [start, stop).

        Identical to ``dense_pairs[start:stop]`` of the other routes:
        real pairs in slot order below the true count (clipped at
        ``cap``), −1 pads above it.
        """
        stop = self._check_window(start, stop)
        nreq = stop - start
        if nreq == 0:
            return emit_kernel._empty_pairs()
        if self.tab is None:
            return jnp.full((nreq, 2), -1, jnp.int32)
        # pow2 ladder: O(lg cap) compiled window sizes per plan, and the
        # dynamic start means re-decoding elsewhere never retraces.
        nslots = max(128, 1 << (nreq - 1).bit_length())
        out = emit_kernel.csr_decode_window(
            self.tab, self.perm_s_pad, self.perm_u_pad,
            jnp.int32(start), n=self.n, m=self.m, nslots=nslots,
            block=self.block, interpret=self.interpret)
        return out[:nreq]

    def __repr__(self) -> str:
        return (f"{type(self).__name__}(cap={self.cap}, "
                f"count={self.count}, n={self.n}, m={self.m}, "
                f"nbytes={self.nbytes}, "
                f"dense_nbytes={self.dense_nbytes})")


@functools.partial(jax.jit, static_argnames=("max_pairs", "block"))
def _csr_tables(s_lo, s_hi, u_lo, u_hi, max_pairs, block):
    """Pass 1 + CSR packing for the csr emit route (all XLA)."""
    n, m = s_lo.shape[0], u_lo.shape[0]
    perm_s, perm_u, starts, counts, offs, cnt_a, cnt_b = _twopass_phase1(
        s_lo, s_hi, u_lo, u_hi, max_pairs)
    bl = emit_kernel.lane_pad(block)
    tab = emit_kernel.pack_emitter_tables(
        offs, counts, starts, n=n, m=m,
        min_len=emit_kernel.stream_window(bl))
    ps = emit_kernel.pad_perm_for_runs(perm_s, bl)
    pu = emit_kernel.pad_perm_for_runs(perm_u, bl)
    return tab, ps, pu, cnt_a, cnt_b


def twopass_pairs_csr(S: Regions, U: Regions, max_pairs: int, *,
                      block: int = emit_kernel.DEF_BLOCK,
                      interpret: bool = False):
    """CSR emit route: ``(CSRPairs view, exact count)``.

    Same count/truncation contract as the dense routes, but the first
    element is a lazy ``CSRPairs`` over the compressed form — the dense
    ``(max_pairs, 2)`` buffer is never materialized here, which is what
    keeps the quadratic-K path O(n+m) in device memory.
    """
    assert S.d == 1
    if S.n == 0 or U.n == 0:
        return CSRPairs.empty(max_pairs, n=S.n, m=U.n, block=block,
                              interpret=interpret), 0
    tab, ps, pu, cnt_a, cnt_b = _csr_tables(
        S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0], max_pairs, block)
    count = int(np.sum(np.asarray(cnt_a), dtype=np.int64)
                + np.sum(np.asarray(cnt_b), dtype=np.int64))
    view = CSRPairs(tab, ps, pu, n=S.n, m=U.n, cap=max_pairs,
                    count=count, block=block, interpret=interpret)
    return view, count


def twopass_pairs_pallas(S: Regions, U: Regions, max_pairs: int, *,
                         block: int = emit_kernel.DEF_BLOCK,
                         interpret: bool = False, route: str = "auto",
                         budget: int | None = None,
                         dense_only: bool = False):
    """Exact 1-D pair enumeration, pass 2 fused into one Pallas kernel.

    Pass 1 (sort + searchsorted counts + saturated offset scan) stays on
    XLA; the slot→(emitter, rank) lookup and the pair write run as a
    ``kernels.emit`` Mosaic kernel.  Same contract as
    ``core.sbm.sbm_pairs``: ``(pairs, exact count)``, truncation
    reports the true K.  ``pairs`` is a dense int32 (max_pairs, 2)
    −1-padded buffer on the resident/streaming/xla routes and a lazy
    ``CSRPairs`` view (identical decoded contents) on the csr route.

    ``route`` picks the emit regime: ``auto`` applies
    ``choose_emit_route`` (resident tables → streamed tables → csr
    decode view → the bit-identical XLA pass 2 as sizes grow past
    ``budget``); pinning a route bypasses the policy — all four
    produce bit-identical decoded output at any size that compiles,
    which is what the parity tests pin them for.  ``dense_only=True``
    excludes csr from ``auto`` and rejects a pinned ``csr`` (callers
    that must gather from the candidate buffer, e.g. d > 1 verify).
    """
    global _LAST_EMIT_ROUTE
    assert S.d == 1
    if route not in EMIT_ROUTES:
        raise ValueError(f"route must be one of {EMIT_ROUTES}, got {route}")
    if dense_only and route == "csr":
        raise ValueError(
            "emit_route='csr' returns a lazy CSRPairs view, but this "
            "caller needs a dense candidate buffer (d > 1 verify path); "
            "pin 'streaming'/'xla' or leave 'auto'")
    if S.n == 0 or U.n == 0:
        _LAST_EMIT_ROUTE = None
        return jnp.full((max_pairs, 2), -1, jnp.int32), 0
    if route == "auto":
        route = choose_emit_route(S.n, U.n, block=block, budget=budget,
                                  dense_only=dense_only)
    _LAST_EMIT_ROUTE = route
    if route == "xla":
        from ..core.sbm import sbm_pairs
        return sbm_pairs(S, U, max_pairs)
    if route == "csr":
        return twopass_pairs_csr(S, U, max_pairs, block=block,
                                 interpret=interpret)
    perm_s, perm_u, starts, counts, offs, cnt_a, cnt_b = _twopass_tables(
        S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0], max_pairs)
    emit = (emit_kernel.twopass_emit if route == "resident"
            else emit_kernel.twopass_emit_streaming)
    pairs = emit(offs, counts, starts, perm_s, perm_u, n=S.n, m=U.n,
                 max_pairs=max_pairs, block=block, interpret=interpret)
    count = int(np.sum(np.asarray(cnt_a), dtype=np.int64)
                + np.sum(np.asarray(cnt_b), dtype=np.int64))
    return pairs, count


# ---------------------------------------------------------------------------
# hybrid grid+SBM (hsbm) — bucketed pass 1 feeding the same emit kernels
# ---------------------------------------------------------------------------

_HSBM_STATICS = ("ncells", "cap_s", "suf_s", "cap_u", "suf_u", "max_pairs")


@functools.partial(jax.jit, static_argnames=_HSBM_STATICS)
def _hsbm_tables(s_lo, s_hi, u_lo, u_hi, lb, width, *, ncells, cap_s,
                 suf_s, cap_u, suf_u, max_pairs):
    """Hybrid pass 1 (benchmark/count target, mirrors ``_twopass_tables``).

    Returns ``(sid, uid, starts, counts, offs)`` from
    ``core.sbm._hsbm_phase1`` — grid geometry statics come from
    ``core.grid.hsbm_geometry``; ``lb``/``width`` are traced f32
    scalars so only shape/geometry changes retrace.
    """
    return _hsbm_phase1(s_lo, s_hi, u_lo, u_hi, lb, width, ncells=ncells,
                        cap_s=cap_s, suf_s=suf_s, cap_u=cap_u,
                        suf_u=suf_u, max_pairs=max_pairs)


@functools.partial(jax.jit, static_argnames=_HSBM_STATICS + ("block",))
def _hsbm_csr_tables(s_lo, s_hi, u_lo, u_hi, lb, width, *, ncells, cap_s,
                     suf_s, cap_u, suf_u, max_pairs, block):
    """Hybrid pass 1 + CSR packing (mirrors ``_csr_tables``)."""
    sid, uid, starts, counts, offs = _hsbm_phase1(
        s_lo, s_hi, u_lo, u_hi, lb, width, ncells=ncells, cap_s=cap_s,
        suf_s=suf_s, cap_u=cap_u, suf_u=suf_u, max_pairs=max_pairs)
    n_a = ncells * (cap_s + suf_s)
    n_b = ncells * (cap_u + suf_u)
    bl = emit_kernel.lane_pad(block)
    tab = emit_kernel.pack_emitter_tables(
        offs, counts, starts, n=n_a, m=n_b,
        min_len=emit_kernel.stream_window(bl))
    ps = emit_kernel.pad_perm_for_runs(sid + n_a, bl)
    pu = emit_kernel.pad_perm_for_runs(uid + n_b, bl)
    return tab, ps, pu, sid, uid, counts


class HsbmCSRPairs(CSRPairs):
    """CSR view over the hybrid pass 1 — decodes to original ids.

    The packed table and padded "permutations" live in the hybrid's
    emitter-slot space (``n``/``m`` are the flattened table sizes
    ``n_emit_s``/``n_emit_u``, the id tables are shifted by them);
    ``decode`` runs the stock CSR kernel and then
    ``kernels.emit.remap_slot_pairs`` — so every window is
    bit-identical to the hybrid XLA pass 2, and ``windows()`` /
    ``to_dense()`` / ``__array__`` inherit that through ``decode``.
    """

    def __init__(self, *args, sid=None, uid=None, **kwargs):
        super().__init__(*args, **kwargs)
        self.sid = sid
        self.uid = uid

    @property
    def nbytes(self) -> int:
        """Compressed form + the slot→id remap tables it decodes with."""
        base = CSRPairs.nbytes.fget(self)
        if self.tab is None:
            return base
        return base + 4 * int(self.sid.size + self.uid.size)

    def decode(self, start: int = 0, stop: int | None = None):
        out = super().decode(start, stop)
        if self.tab is None or out.shape[0] == 0:
            return out
        return emit_kernel.remap_slot_pairs(out, self.sid, self.uid,
                                            n_a=self.n, n_b=self.m)


def hsbm_pairs_pallas(S: Regions, U: Regions, max_pairs: int, *,
                      geom=None, ncells: int | None = None,
                      block: int = emit_kernel.DEF_BLOCK,
                      interpret: bool = False, route: str = "auto",
                      budget: int | None = None,
                      dense_only: bool = False):
    """Hybrid grid+SBM pair enumeration through the Pallas emit kernels.

    Same contract and route policy as ``twopass_pairs_pallas`` — the
    hybrid's flattened per-cell emitter tables simply take the place of
    the flat path's n/m emitters (so ``choose_emit_route`` sees the
    padded table sizes, which is what actually determines VMEM need).
    All four routes produce identical decoded output: the kernels run
    in emitter-slot space and ``kernels.emit.remap_slot_pairs`` maps
    back to original region ids; the xla route emits original ids
    directly (``core.sbm._hsbm_emit``).  ``geom`` (an
    ``HsbmGeometry``) skips the host measurement; otherwise geometry
    is measured here, with ``ncells`` overriding the heuristic grid.
    """
    global _LAST_EMIT_ROUTE
    assert S.d == 1
    if route not in EMIT_ROUTES:
        raise ValueError(f"route must be one of {EMIT_ROUTES}, got {route}")
    if dense_only and route == "csr":
        raise ValueError(
            "emit_route='csr' returns a lazy CSRPairs view, but this "
            "caller needs a dense candidate buffer (d > 1 verify path); "
            "pin 'streaming'/'xla' or leave 'auto'")
    if S.n == 0 or U.n == 0:
        _LAST_EMIT_ROUTE = None
        return jnp.full((max_pairs, 2), -1, jnp.int32), 0
    s_lo, s_hi = S.lo[:, 0], S.hi[:, 0]
    u_lo, u_hi = U.lo[:, 0], U.hi[:, 0]
    if geom is None:
        from ..core.grid import hsbm_geometry
        geom = hsbm_geometry(s_lo, s_hi, u_lo, u_hi, ncells=ncells)
    n_a, n_b = geom.n_emit_s, geom.n_emit_u
    if route == "auto":
        route = choose_emit_route(n_a, n_b, block=block, budget=budget,
                                  dense_only=dense_only)
    _LAST_EMIT_ROUTE = route
    lb = jnp.float32(geom.lb)
    width = jnp.float32(geom.width)
    if route == "xla":
        from ..core.sbm import _hsbm_emit
        pairs, counts = _hsbm_emit(s_lo, s_hi, u_lo, u_hi, lb, width,
                                   max_pairs=max_pairs, **geom.statics())
        return pairs, int(np.sum(np.asarray(counts), dtype=np.int64))
    if route == "csr":
        tab, ps, pu, sid, uid, counts = _hsbm_csr_tables(
            s_lo, s_hi, u_lo, u_hi, lb, width, max_pairs=max_pairs,
            block=block, **geom.statics())
        count = int(np.sum(np.asarray(counts), dtype=np.int64))
        view = HsbmCSRPairs(tab, ps, pu, n=n_a, m=n_b, cap=max_pairs,
                            count=count, block=block, interpret=interpret,
                            sid=sid, uid=uid)
        return view, count
    sid, uid, starts, counts, offs = _hsbm_tables(
        s_lo, s_hi, u_lo, u_hi, lb, width, max_pairs=max_pairs,
        **geom.statics())
    emit = (emit_kernel.twopass_emit if route == "resident"
            else emit_kernel.twopass_emit_streaming)
    slots = emit(offs, counts, starts, sid + n_a, uid + n_b, n=n_a,
                 m=n_b, max_pairs=max_pairs, block=block,
                 interpret=interpret)
    pairs = emit_kernel.remap_slot_pairs(slots, sid, uid, n_a=n_a,
                                         n_b=n_b)
    count = int(np.sum(np.asarray(counts), dtype=np.int64))
    return pairs, count


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _sweep(s_lo, s_hi, u_lo, u_hi, block, interpret):
    is_lo, is_upd = _endpoint_stream(s_lo, s_hi, u_lo, u_hi)
    tot = is_lo.shape[0]
    pad = (-tot) % block
    # sub-lo sentinels: zero contribution, only bump sub_active at the end
    is_lo = jnp.pad(is_lo, (0, pad), constant_values=1)
    is_upd = jnp.pad(is_upd, (0, pad), constant_values=0)
    out = sweep_kernel.sbm_sweep(is_lo, is_upd, block=block,
                                 interpret=interpret)
    return out[:tot]


def sbm_count_pallas(S: Regions, U: Regions, *, block: int = 2048,
                     interpret: bool = False) -> int:
    """Total K via sort (XLA) + Pallas sweep kernel. 1-D regions."""
    assert S.d == 1
    if S.n == 0 or U.n == 0:
        return 0
    c = _sweep(S.lo[:, 0], S.hi[:, 0], U.lo[:, 0], U.hi[:, 0],
               block, interpret)
    return int(np.sum(np.asarray(c), dtype=np.int64))
