"""Tiled brute-force interval matching — the BFM/GBM hot loop as a
Pallas TPU kernel.

Paper Algorithm 2 is a branchy scalar double loop; the TPU form is a
2-D grid over (S-tiles × U-tiles).  Each program holds a (TS, d) block of
subscription bounds and a (TU, d) block of update bounds in VMEM, forms
the (TS, TU) overlap predicate with broadcast compares on the VPU (one
pair of compares per dimension, AND-reduced), and emits either the
per-tile intersection count (BFM counting mode — what the paper's
evaluation measures) or the boolean tile of the match mask (the DDM
block-mask planner used by block-sparse attention).

VMEM budget per program: TS·d + TU·d floats + TS·TU predicate ≈
2·(256·d)·4B + 256·256 ≈ 70 KiB for d≤4 — comfortably inside the ~16 MiB
VMEM of a v5e core, leaving room for double buffering.  TS=TU=256 keeps
the compare block a multiple of the (8, 128) VPU tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEF_TS = 256
DEF_TU = 256


def _count_kernel(s_lo_ref, s_hi_ref, u_lo_ref, u_hi_ref, out_ref):
    d = s_lo_ref.shape[-1]
    ok = None
    for k in range(d):
        slo = s_lo_ref[:, k][:, None]
        shi = s_hi_ref[:, k][:, None]
        ulo = u_lo_ref[:, k][None, :]
        uhi = u_hi_ref[:, k][None, :]
        dim_ok = (slo < uhi) & (ulo < shi)
        ok = dim_ok if ok is None else (ok & dim_ok)
    out_ref[0, 0] = jnp.sum(ok.astype(jnp.int32))


def _mask_kernel(s_lo_ref, s_hi_ref, u_lo_ref, u_hi_ref, out_ref):
    d = s_lo_ref.shape[-1]
    ok = None
    for k in range(d):
        slo = s_lo_ref[:, k][:, None]
        shi = s_hi_ref[:, k][:, None]
        ulo = u_lo_ref[:, k][None, :]
        uhi = u_hi_ref[:, k][None, :]
        dim_ok = (slo < uhi) & (ulo < shi)
        ok = dim_ok if ok is None else (ok & dim_ok)
    out_ref[...] = ok


@functools.partial(jax.jit,
                   static_argnames=("ts", "tu", "interpret"))
def bfm_tile_counts(s_lo, s_hi, u_lo, u_hi, *, ts: int = DEF_TS,
                    tu: int = DEF_TU, interpret: bool = False):
    """Per-tile overlap counts int32 (n/ts, m/tu). n%ts == m%tu == 0."""
    n, d = s_lo.shape
    m = u_lo.shape[0]
    assert n % ts == 0 and m % tu == 0, (n, ts, m, tu)
    grid = (n // ts, m // tu)
    return pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ts, d), lambda i, j: (i, 0)),
            pl.BlockSpec((ts, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tu, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tu, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(grid, jnp.int32),
        interpret=interpret,
    )(s_lo, s_hi, u_lo, u_hi)


@functools.partial(jax.jit,
                   static_argnames=("ts", "tu", "interpret"))
def bfm_mask(s_lo, s_hi, u_lo, u_hi, *, ts: int = DEF_TS,
             tu: int = DEF_TU, interpret: bool = False):
    """Full (n, m) bool overlap mask, tiled. n%ts == m%tu == 0."""
    n, d = s_lo.shape
    m = u_lo.shape[0]
    assert n % ts == 0 and m % tu == 0, (n, ts, m, tu)
    grid = (n // ts, m // tu)
    return pl.pallas_call(
        _mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ts, d), lambda i, j: (i, 0)),
            pl.BlockSpec((ts, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tu, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tu, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ts, tu), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.bool_),
        interpret=interpret,
    )(s_lo, s_hi, u_lo, u_hi)
