"""Pallas TPU kernels (validated in interpret mode on CPU)."""
from . import bfm, sbm_sweep, ops, ref
