"""DDM-planned block-sparse flash attention (Pallas TPU kernel).

Consumes the per-q-block [start, end) kv token windows produced by
``repro.sparse.planner`` (i.e. by the paper's interval matcher) plus the
sink prefix, and computes attention with an online-softmax accumulator —
each program owns one q block, walks the sink blocks then its kv window
in ``block_kv`` steps with dynamic ``pl.ds`` loads, so only
(block_q × block_kv) tiles are ever live in VMEM and nothing quadratic is
materialized.

Layout per program: q (bq, dh) VMEM block; k/v full arrays (the
test/validation sizes fit; a production variant would keep k/v in ANY
space and DMA tiles — same index arithmetic).  starts/ends ride along as
(nq,) int32 arrays.

Validated in interpret mode against ``ref.windowed_attention`` +
dense-masked attention in tests; ``repro.sparse.attention`` is the jnp
fallback used on non-TPU backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(qid_ref, starts_ref, ends_ref, q_ref, k_ref, v_ref, o_ref, *,
            bq: int, bkv: int, sink_end: int, scale: float):
    # NB: the q-block index arrives as a blocked (1,) input instead of
    # pl.program_id so the same kernel body works for any grid prefix
    # (the batch·head axis is grid dim 0).
    i = qid_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale            # (bq, dh)
    dh = q.shape[-1]
    q_pos = i * bq + jax.lax.iota(jnp.int32, bq)        # (bq,)

    start = starts_ref[0]
    end = ends_ref[0]

    def attend(kv_off, carry):
        acc, m, l = carry
        # leading index as a 1-slice (not a bare int): older Pallas
        # interpret-mode discharge only accepts Slice/array indices
        kblk = pl.load(k_ref, (pl.ds(0, 1), pl.ds(kv_off, bkv),
                               slice(None)))[0]
        vblk = pl.load(v_ref, (pl.ds(0, 1), pl.ds(kv_off, bkv),
                               slice(None)))[0]
        s = q @ kblk.astype(jnp.float32).T               # (bq, bkv)
        kv_pos = kv_off + jax.lax.iota(jnp.int32, bkv)
        ok = (kv_pos[None, :] <= q_pos[:, None]) & \
             (kv_pos[None, :] < end)
        s = jnp.where(ok, s, NEG_INF)
        m2 = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m2)
        p = jnp.exp(s - m2[:, None])
        l2 = l * alpha + jnp.sum(p, axis=1)
        acc2 = acc * alpha[:, None] + p @ vblk.astype(jnp.float32)
        return acc2, m2, l2

    acc = jnp.zeros((bq, dh), jnp.float32)
    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)

    # sink prefix [0, sink_end): static trip count
    for j in range(sink_end // bkv):
        acc, m, l = attend(j * bkv, (acc, m, l))

    # DDM window [start, end): dynamic trip count
    start_blk = jnp.maximum(start, sink_end) // bkv
    n_blocks = (end - start_blk * bkv + bkv - 1) // bkv

    def body(j, carry):
        return attend(start_blk * bkv + j * bkv, carry)

    acc, m, l = jax.lax.fori_loop(0, n_blocks, body, (acc, m, l))
    safe_l = jnp.where(l > 0, l, 1.0)
    o_ref[0] = (acc / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "sink_end",
                                             "interpret"))
def _sparse_attn_bh(q, k, v, starts, ends, *, bq: int, bkv: int,
                    sink_end: int, interpret: bool):
    """q/k/v: (BH, S, dh) — grid (BH, nq)."""
    BH, Sq, dh = q.shape
    nq = Sq // bq
    scale = dh ** -0.5
    kern = functools.partial(_kernel, bq=bq, bkv=bkv, sink_end=sink_end,
                             scale=scale)
    qids = jnp.arange(nq, dtype=jnp.int32)
    return pl.pallas_call(
        kern,
        grid=(BH, nq),
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (i,)),
            pl.BlockSpec((1,), lambda b, i: (i,)),
            pl.BlockSpec((1,), lambda b, i: (i,)),
            pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1,) + k.shape[1:], lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1,) + v.shape[1:], lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        interpret=interpret,
    )(qids, starts, ends, q, k, v)


def sparse_attn_1h(q, k, v, starts, ends, *, bq: int = 128,
                   bkv: int = 128, sink_end: int = 0,
                   interpret: bool = False):
    """Single-head: q (Sq, dh), k/v (Skv, dh), starts/ends (nq,) int32."""
    Sq, dh = q.shape
    assert Sq % bq == 0, (Sq, bq)
    assert starts.shape == (Sq // bq,) and ends.shape == (Sq // bq,)
    out = _sparse_attn_bh(q[None], k[None], v[None], starts, ends,
                          bq=bq, bkv=bkv, sink_end=sink_end,
                          interpret=interpret)
    return out[0]


def sparse_attn(q, k, v, starts, ends, *, bq: int = 128, bkv: int = 128,
                sink_end: int = 0, interpret: bool = False):
    """Batched multi-head: q/k/v (B, S, H, dh) — batch·head = grid dim 0."""
    B, S, H, dh = q.shape
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, dh)  # noqa
    out = _sparse_attn_bh(fold(q), fold(k), fold(v), starts, ends,
                          bq=bq, bkv=bkv, sink_end=sink_end,
                          interpret=interpret)
    return out.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
