"""Pure-jnp oracles for every Pallas kernel in this package.

Each function computes exactly what the corresponding kernel computes,
with no tiling — tests assert_allclose(kernel(interpret=True), ref(...))
across shape/dtype sweeps.
"""
from __future__ import annotations

import jax.numpy as jnp


def bfm_tile_counts(s_lo, s_hi, u_lo, u_hi, ts: int, tu: int):
    """Per-(S-tile, U-tile) overlap counts, int32 (n/ts, m/tu).

    Inputs are (n, d)/(m, d) float arrays, n % ts == m % tu == 0.
    """
    n, m = s_lo.shape[0], u_lo.shape[0]
    ok = jnp.all((s_lo[:, None, :] < u_hi[None, :, :]) &
                 (u_lo[None, :, :] < s_hi[:, None, :]), axis=-1)
    return ok.reshape(n // ts, ts, m // tu, tu).sum(
        axis=(1, 3), dtype=jnp.int32)


def bfm_mask(s_lo, s_hi, u_lo, u_hi):
    """Full (n, m) bool overlap mask."""
    return jnp.all((s_lo[:, None, :] < u_hi[None, :, :]) &
                   (u_lo[None, :, :] < s_hi[:, None, :]), axis=-1)


def chunked_scan(x):
    """Inclusive prefix sum over a 1-D int32 vector."""
    return jnp.cumsum(x)


def sbm_sweep(is_lo, is_upd):
    """Per-endpoint SBM report counts given the lex-sorted endpoint
    stream flags (1-D int32 arrays).  Mirrors core.sbm._sweep_contribs
    post-sort."""
    is_hi = 1 - is_lo
    is_sub = 1 - is_upd
    upd_active = jnp.cumsum(is_upd * is_lo) - jnp.cumsum(is_upd * is_hi)
    sub_active = jnp.cumsum(is_sub * is_lo) - jnp.cumsum(is_sub * is_hi)
    return (is_hi * (is_sub * upd_active + is_upd * sub_active)
            ).astype(jnp.int32)


def windowed_attention(q, k, v, starts, ends, blk_q: int):
    """Block-sparse causal-window attention oracle.

    q: (sq, dh), k/v: (skv, dh); query block i attends to kv positions
    [starts[i], ends[i]) (precomputed by the DDM planner).  fp32 softmax.
    """
    sq, dh = q.shape
    skv = k.shape[0]
    scores = (q.astype(jnp.float32) @ k.astype(jnp.float32).T
              ) / jnp.sqrt(jnp.float32(dh))
    pos = jnp.arange(skv)[None, :]
    qb = jnp.arange(sq)[:, None] // blk_q
    allowed = (pos >= starts[qb]) & (pos < ends[qb])
    scores = jnp.where(allowed, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)


import jax  # noqa: E402  (used by windowed_attention)
