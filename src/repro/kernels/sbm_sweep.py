"""SBM sweep phase as a Pallas TPU kernel — paper Alg. 6/7 at the
VMEM-block level.

After the endpoint sort, the sweep is two prefix sums over ±1 deltas plus
a pointwise report expression (see ``core.sbm``).  On TPU this maps to
the paper's own two-level scan, one level down the memory hierarchy: the
grid walks the endpoint stream in (1, C) VMEM blocks **sequentially**
(TPU grid order is sequential, which is what makes a carried scan legal);
each program computes the local inclusive scans of the update/
subscription active-deltas — Alg. 7 step ① — adds the carry from all
previous blocks — step ② — and emits the per-endpoint report counts of
the seeded sweep — step ③.  The two carries (active update/sub counts)
live in SMEM scratch across grid steps.

Inputs are the lex-sorted endpoint flags, already padded to a multiple of
the block size with zero rows (zero flags contribute nothing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sweep_kernel(is_lo_ref, is_upd_ref, out_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = 0  # active updates before this block
        carry_ref[1] = 0  # active subscriptions before this block

    is_lo = is_lo_ref[...]                   # (1, C) int32
    is_upd = is_upd_ref[...]
    is_hi = 1 - is_lo
    is_sub = 1 - is_upd

    d_upd = is_upd * (is_lo - is_hi)
    d_sub = is_sub * (is_lo - is_hi)
    upd_local = jnp.cumsum(d_upd, axis=1)    # step ① local scan
    sub_local = jnp.cumsum(d_sub, axis=1)
    upd_active = upd_local + carry_ref[0]    # step ② seeded
    sub_active = sub_local + carry_ref[1]
    out_ref[...] = is_hi * (is_sub * upd_active + is_upd * sub_active)

    carry_ref[0] = carry_ref[0] + jnp.sum(d_upd)
    carry_ref[1] = carry_ref[1] + jnp.sum(d_sub)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sbm_sweep(is_lo, is_upd, *, block: int = 2048,
              interpret: bool = False):
    """Per-endpoint report counts; 1-D int32 inputs, len % block == 0.

    Note: padded tail rows must have ``is_lo = is_upd = 0``; such rows are
    treated as (hi, sub) endpoints and contribute ``upd_active`` — so use
    the canonical padding (is_lo=1, is_upd=0: a sub-lo sentinel) from
    ``ops.sbm_sweep_contribs`` which contributes exactly zero.
    """
    tot = is_lo.shape[0]
    assert tot % block == 0, (tot, block)
    grid = (tot // block,)
    out = pl.pallas_call(
        _sweep_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, tot), jnp.int32),
        scratch_shapes=[pltpu.SMEM((2,), jnp.int32)],
        interpret=interpret,
    )(is_lo.reshape(1, -1), is_upd.reshape(1, -1))
    return out.reshape(-1)
