"""Fused two-pass emit — pass 2 of count-then-emit as one Pallas kernel.

Pass 1 of the exact pair enumeration (``core.sbm._twopass_phase1``)
produces per-emitter counts and saturated exclusive-scan output offsets
on the XLA side (sort + searchsorted are already near-roofline there).
Pass 2 — the slot→(emitter, rank) lookup and the pair write — was an
XLA ``searchsorted`` + two gathers with three HBM round-trips between
them; here it is ONE kernel: the grid walks the output buffer in
(1, B) blocks, each program binary-searches the offset table held in
VMEM for its B slots (lg(n+m) steps, all lanes in lock-step), derives
the emitter-local rank, and writes both pair halves — offsets, counts,
start table and the two sort permutations are read once into VMEM and
reused by every program.

Slot semantics match the XLA pass 2 bit-for-bit: slot ``t`` belongs to
the last emitter ``e`` with ``offs[e] <= t``; its rank is
``t − offs[e]``; ranks at or beyond the emitter's count (saturated
region, or ``t`` past the total) emit the −1 pad.  Class-A emitters
(``e < n``) own subscription ``e`` and read the update id from the
lo-sorted U permutation; class-B emitters own update ``e − n`` and read
the subscription id from the lo-sorted S permutation.

Lane-dim tables are padded to 128 multiples with sentinels (offsets:
INT32_MAX/2, never ≤ any slot id; counts/starts: 0) so padding can never
be selected by the search.

VMEM budget: the five tables are ≈ (3·(n+m) + n + m) int32 words held
resident for the whole grid; the ``kernels.ops`` wrapper routes problems
past its byte budget to the bit-identical XLA pass 2 (streaming the
tables through double-buffered DMA is the ROADMAP follow-up for
n+m ≫ 1e6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_PAD_OFF = (1 << 30)  # > any slot id; padded offsets are never selected
DEF_BLOCK = 512


def _emit_kernel(offs_ref, counts_ref, starts_ref, perm_s_ref, perm_u_ref,
                 s_out_ref, u_out_ref, *, n: int, m: int, block: int):
    i = pl.program_id(0)
    E = n + m
    offs = offs_ref[0, :]
    counts = counts_ref[0, :]
    starts = starts_ref[0, :]

    t = i * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    t = t[0, :]

    # binary search: largest e in [0, E] with offs[e] <= t  (== the XLA
    # searchsorted(offs, t, side="right") - 1; offs[0] == 0 <= t always)
    lo = jnp.zeros_like(t)
    hi = jnp.full_like(t, E)
    for _ in range(max(E.bit_length(), 1)):
        mid = (lo + hi + 1) >> 1
        go_right = jnp.take(offs, mid) <= t
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid - 1)
    e = lo

    j = t - jnp.take(offs, e)
    e_c = jnp.minimum(e, E - 1)
    valid = (e < E) & (j >= 0) & (j < jnp.take(counts, e_c))
    start = jnp.take(starts, e_c)
    is_a = e_c < n
    u_from_a = jnp.take(perm_u_ref[0, :], jnp.clip(start + j, 0, m - 1))
    s_from_b = jnp.take(perm_s_ref[0, :], jnp.clip(start + j, 0, n - 1))
    s_idx = jnp.where(valid, jnp.where(is_a, e_c, s_from_b), -1)
    u_idx = jnp.where(valid, jnp.where(is_a, u_from_a, e_c - n), -1)
    s_out_ref[0, :] = s_idx
    u_out_ref[0, :] = u_idx


def _pad_lanes(x, fill, mult: int = 128):
    pad = (-x.shape[0]) % mult
    if pad:
        x = jnp.pad(x, (0, pad), constant_values=fill)
    return x.reshape(1, -1)


@functools.partial(jax.jit,
                   static_argnames=("n", "m", "max_pairs", "block",
                                    "interpret"))
def twopass_emit(offs, counts, starts, perm_s, perm_u, *, n: int, m: int,
                 max_pairs: int, block: int = DEF_BLOCK,
                 interpret: bool = False):
    """Pass-2 pair write: (max_pairs, 2) int32, −1 padded.

    ``offs`` is the (n+m+1,) saturated exclusive scan from pass 1,
    ``counts``/``starts`` the (n+m,) per-emitter tables, ``perm_s``/
    ``perm_u`` the lo-sort permutations.  Output slot order is identical
    to the XLA pass 2 in ``core.sbm._twopass_emit``.
    """
    bl = min(block, max(128, max_pairs))
    t_pad = (-max_pairs) % bl
    total = max_pairs + t_pad
    grid = (total // bl,)
    offs_p = _pad_lanes(offs, _PAD_OFF)
    counts_p = _pad_lanes(counts, 0)
    starts_p = _pad_lanes(starts, 0)
    perm_s_p = _pad_lanes(perm_s, 0)
    perm_u_p = _pad_lanes(perm_u, 0)

    full = lambda arr: pl.BlockSpec(arr.shape, lambda i: (0, 0))
    s_out, u_out = pl.pallas_call(
        functools.partial(_emit_kernel, n=n, m=m, block=bl),
        grid=grid,
        in_specs=[full(offs_p), full(counts_p), full(starts_p),
                  full(perm_s_p), full(perm_u_p)],
        out_specs=(pl.BlockSpec((1, bl), lambda i: (0, i)),
                   pl.BlockSpec((1, bl), lambda i: (0, i))),
        out_shape=(jax.ShapeDtypeStruct((1, total), jnp.int32),
                   jax.ShapeDtypeStruct((1, total), jnp.int32)),
        interpret=interpret,
    )(offs_p, counts_p, starts_p, perm_s_p, perm_u_p)
    return jnp.stack([s_out[0, :max_pairs], u_out[0, :max_pairs]], axis=1)
