"""Fused two-pass emit — pass 2 of count-then-emit as one Pallas kernel.

Pass 1 of the exact pair enumeration (``core.sbm._twopass_phase1``)
produces per-emitter counts and saturated exclusive-scan output offsets
on the XLA side (sort + searchsorted are already near-roofline there).
Pass 2 — the slot→(emitter, rank) lookup and the pair write — was an
XLA ``searchsorted`` + two gathers with three HBM round-trips between
them; here it is ONE kernel, in two size regimes:

``twopass_emit`` (resident)
    The grid walks the output buffer in (1, B) blocks; offsets, counts,
    start table and the two sort permutations are read once into VMEM
    and reused by every program.  Each program binary-searches the
    offset table for its B slots (lg(n+m) steps, all lanes in
    lock-step), derives the emitter-local rank, and writes both pair
    halves.  Runs while all five tables fit the VMEM budget
    (≈ 4·(n+m) int32 words).

``twopass_emit_streaming`` (tiled, double-buffered DMA)
    For the paper's N ≥ 1e6 regime the offset/count/start tables no
    longer fit VMEM.  The XLA side first *compacts* the emitter tables
    to the emitters with non-zero counts — compacted offsets are
    strictly increasing below the saturation limit, so the emitters
    addressed by one B-slot output tile span at most B + 1 consecutive
    compacted entries.  It then computes each tile's 128-aligned base
    index into the compacted tables (a searchsorted over the tile's
    first slot) and hands those bounds to the kernel as a
    scalar-prefetch argument.  The kernel keeps the packed
    (offs/counts/starts/emitter-id) table in HBM (``ANY`` memory
    space) and double-buffers (B + 256)-wide slices of it through a
    two-slot VMEM scratch with ``make_async_copy``: while tile ``i``
    binary-searches its window and writes its pairs, the DMA for tile
    ``i + 1``'s window is already in flight.  Only the two sort
    permutations stay VMEM-resident — their gather indices
    (``start + rank``) are data-dependent and non-local, so no per-tile
    slice of them exists; they are also the smallest quarter of the
    table bytes, which is what extends the Pallas route's reach ~4×
    (to n+m ≈ 2e6 under the default 8 MiB budget) before the XLA
    fallback takes over.

``csr_decode_window`` (CSR route: constant VMEM, nothing resident)
    Past n+m ≈ 2e6 even the bare permutations outgrow VMEM, and for
    quadratic-K workloads the dense ``(K, 2)`` output dominates HBM.
    The CSR route drops both: pass 1's tables *are* a CSR matrix
    (per-emitter offset + contiguous rank range into a sort
    permutation), so the route keeps only the packed compacted table
    plus the two permutations in HBM — O(n+m) words, never O(K) — and
    decodes any window of slots on demand.  The decode kernel holds a
    per-tile table window (same packing and bound as the streaming
    route) and streams the *permutation runs* by DMA: the slots of one
    output tile select a contiguous range of compacted emitters, and
    each selected emitter contributes one contiguous ``block``-bounded
    run of a permutation, so the tile issues at most one fixed-length
    descriptor per selected emitter (``<= block + 1`` of them).  Runs
    land in slot order in a scratch line; copies are issued in
    ascending emitter order so a later run overwrites any earlier
    run's fixed-length overhang — the slot's owner (the *last* emitter
    with ``offs[e] <= t``) always writes last.  VMEM use is a constant
    ``8·win + 2·block`` int32 lanes regardless of n + m, which is what
    lifts the Pallas emit bound into the 1e7–1e8 region regime.  The
    lazy ``MatchPlan.pairs()`` view over this kernel lives in
    ``kernels.ops.CSRPairs``.

Slot semantics match the XLA pass 2 bit-for-bit in both regimes: slot
``t`` belongs to the last emitter ``e`` with ``offs[e] <= t``; its rank
is ``t − offs[e]``; ranks at or beyond the emitter's count (saturated
region, or ``t`` past the total) emit the −1 pad.  Class-A emitters
(``e < n``) own subscription ``e`` and read the update id from the
lo-sorted U permutation; class-B emitters own update ``e − n`` and read
the subscription id from the lo-sorted S permutation.  Compaction in
the streaming path cannot change any emitted pair: a slot's selected
emitter is the *last* one at its offset value, which always has a
non-zero count (zero-count emitters share their offset with a
successor, so they are never last).

Lane-dim tables are padded to 128 multiples with sentinels (offsets:
INT32_MAX/2, never ≤ any slot id; counts/starts: 0; emitter ids: n+m)
so padding can never produce a valid slot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_PAD_OFF = (1 << 30)  # > any slot id; padded offsets are never selected
DEF_BLOCK = 512
# streaming window: one output tile of B slots addresses <= B + 1
# consecutive compacted emitters; +128 covers aligning the window base
# down to a lane multiple, and the total stays a lane multiple itself.
STREAM_WIN_EXTRA = 256


def lane_pad(x: int, mult: int = 128) -> int:
    """Round ``x`` up to a lane multiple (the kernels' padded table size)."""
    return -(-x // mult) * mult


def stream_window(block: int) -> int:
    """Streaming DMA window length (int32 lanes) for an emit ``block``.

    The single source of truth for the window size: the streaming
    kernel's VMEM scratch is ``(2, 8, stream_window(block))`` and the
    route policy's byte model (``kernels.ops.emit_route_bytes``) charges
    exactly these lanes — the static auditor asserts the two never
    drift apart.
    """
    return lane_pad(block) + STREAM_WIN_EXTRA


def _empty_pairs():
    return jnp.zeros((0, 2), jnp.int32)


def _block_slots(i, block: int):
    t = i * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    return t[0, :]


def _search_last_le(offs, t, span: int):
    """Largest k in [0, span) with offs[k] <= t, per lane of ``t``."""
    lo = jnp.zeros_like(t)
    hi = jnp.full_like(t, span - 1)
    for _ in range(max((span - 1).bit_length(), 1)):
        mid = (lo + hi + 1) >> 1
        go_right = jnp.take(offs, mid) <= t
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid - 1)
    return lo


def _pair_halves(e, j, start, cnt, perm_s_ref, perm_u_ref, *, n: int,
                 m: int):
    """Both pair halves for emitter ``e`` / rank ``j`` (−1 when invalid).

    ``e`` is the original emitter id (may be the n+m sentinel on padded
    window entries — those carry ``cnt == 0`` and fall to the pad).
    """
    valid = (j >= 0) & (j < cnt)
    is_a = e < n
    u_from_a = jnp.take(perm_u_ref[0, :], jnp.clip(start + j, 0, m - 1))
    s_from_b = jnp.take(perm_s_ref[0, :], jnp.clip(start + j, 0, n - 1))
    s_idx = jnp.where(valid, jnp.where(is_a, e, s_from_b), -1)
    u_idx = jnp.where(valid, jnp.where(is_a, u_from_a, e - n), -1)
    return s_idx, u_idx


@functools.partial(jax.jit, static_argnames=("n_a", "n_b"))
def remap_slot_pairs(pairs, sid, uid, *, n_a: int, n_b: int):
    """Map slot-space pair halves back to original region ids (hsbm).

    The hybrid grid+SBM pass 1 (``core.sbm._hsbm_phase1``) reuses every
    emit kernel unchanged by relabeling: its ``n_a``/``n_b`` flattened
    emitter-table rows play the roles of the flat path's n/m emitters,
    and the *shifted id tables* ``sid + n_a`` / ``uid + n_b`` play the
    sort permutations.  A kernel-emitted pair half is then either an
    own-emitter slot index (class-A s-half: ``< n_a``; class-B u-half:
    ``< n_b``) or a gathered shifted id (``>= n_a`` resp. ``>= n_b``) —
    the two ranges are disjoint by construction.  This helper undoes
    the encoding: −1 pads pass through, slot values gather the id
    table, shifted values subtract the shift.  Valid slots never
    gather a pad row of the id tables (emitter windows only cover real
    natives), so the result is exactly the original-id buffer the XLA
    hybrid pass 2 (``core.sbm._hsbm_emit``) writes.
    """
    c0, c1 = pairs[:, 0], pairs[:, 1]
    s_idx = jnp.where(
        c0 < 0, -1,
        jnp.where(c0 < n_a, jnp.take(sid, jnp.clip(c0, 0, n_a - 1)),
                  c0 - n_a))
    u_idx = jnp.where(
        c1 < 0, -1,
        jnp.where(c1 < n_b, jnp.take(uid, jnp.clip(c1, 0, n_b - 1)),
                  c1 - n_b))
    return jnp.stack([s_idx, u_idx], axis=1)


# ---------------------------------------------------------------------------
# resident kernel — all five tables in VMEM for the whole grid
# ---------------------------------------------------------------------------

def _emit_kernel(offs_ref, counts_ref, starts_ref, perm_s_ref, perm_u_ref,
                 s_out_ref, u_out_ref, *, n: int, m: int, block: int):
    i = pl.program_id(0)
    E = n + m
    offs = offs_ref[0, :]
    t = _block_slots(i, block)

    # binary search: largest e in [0, E] with offs[e] <= t  (== the XLA
    # searchsorted(offs, t, side="right") - 1; offs[0] == 0 <= t always)
    e = _search_last_le(offs, t, E + 1)
    j = t - jnp.take(offs, e)
    e_c = jnp.minimum(e, E - 1)
    cnt = jnp.where(e < E, jnp.take(counts_ref[0, :], e_c), 0)
    start = jnp.take(starts_ref[0, :], e_c)
    s_idx, u_idx = _pair_halves(e_c, j, start, cnt, perm_s_ref,
                                perm_u_ref, n=n, m=m)
    s_out_ref[0, :] = s_idx
    u_out_ref[0, :] = u_idx


def _pad_lanes(x, fill, mult: int = 128):
    pad = (-x.shape[0]) % mult
    if pad:
        x = jnp.pad(x, (0, pad), constant_values=fill)
    return x.reshape(1, -1)


def pack_emitter_tables(offs, counts, starts, *, n: int, m: int,
                        min_len: int):
    """Compact + pack pass 1's emitter tables (XLA side, traceable).

    Zero-count emitters are dropped — they share their offset with a
    successor, so the slot lookup (*last* emitter at ``offs <= t``)
    never selects them — leaving compacted offsets strictly increasing
    below saturation, which bounds one B-slot tile's reach to B + 1
    consecutive entries.  Survivors pack into one (8, E_pad) int32
    array: rows 0–3 are saturated offsets / counts / input starts /
    original emitter id; rows 4–7 pad to the 8-sublane int32 tile
    height so HBM window slices stay tile-aligned.  ``min_len`` floors
    E_pad at the widest window a consumer will slice; pad entries
    carry offset ``_PAD_OFF`` and emitter id n + m, so they can never
    be selected by any in-range slot.
    """
    E = n + m
    sel = jnp.nonzero(counts > 0, size=E, fill_value=E)[0].astype(jnp.int32)
    ok = sel < E
    selc = jnp.minimum(sel, E - 1)
    c_offs = jnp.where(ok, offs[selc], _PAD_OFF)
    c_counts = jnp.where(ok, counts[selc], 0)
    c_starts = jnp.where(ok, starts[selc], 0)
    c_eorig = jnp.where(ok, sel, E)

    pad = max((-E) % 128, min_len - E)
    if pad > 0:
        c_offs = jnp.pad(c_offs, (0, pad), constant_values=_PAD_OFF)
        c_counts = jnp.pad(c_counts, (0, pad))
        c_starts = jnp.pad(c_starts, (0, pad))
        c_eorig = jnp.pad(c_eorig, (0, pad), constant_values=E)
    e_pad = c_offs.shape[0]
    tab = jnp.zeros((8, e_pad), jnp.int32)
    tab = tab.at[0].set(c_offs).at[1].set(c_counts)
    tab = tab.at[2].set(c_starts).at[3].set(c_eorig)
    return tab


def pad_perm_for_runs(perm, run: int):
    """Pad a sort permutation for fixed-``run``-length DMA over-reads.

    The CSR decode kernel copies a static ``run`` lanes per selected
    emitter starting at ``start + rank``; the clamp ``rank <= count``
    keeps the copy start inside the real permutation, so ``run`` extra
    lanes past the lane-padded end make every over-read in-bounds.
    """
    return _pad_lanes(jnp.pad(perm, (0, run)), 0)


@functools.partial(jax.jit,
                   static_argnames=("n", "m", "max_pairs", "block",
                                    "interpret"))
def twopass_emit(offs, counts, starts, perm_s, perm_u, *, n: int, m: int,
                 max_pairs: int, block: int = DEF_BLOCK,
                 interpret: bool = False):
    """Pass-2 pair write: (max_pairs, 2) int32, −1 padded.

    ``offs`` is the (n+m+1,) saturated exclusive scan from pass 1,
    ``counts``/``starts`` the (n+m,) per-emitter tables, ``perm_s``/
    ``perm_u`` the lo-sort permutations.  Output slot order is identical
    to the XLA pass 2 in ``core.sbm._twopass_emit``.  ``max_pairs == 0``
    short-circuits to an empty (0, 2) buffer (a zero-size grid is not a
    legal ``pallas_call``), matching the engine's empty-set guarantees.
    """
    if max_pairs == 0:
        return _empty_pairs()
    bl = min(block, max(128, max_pairs))
    t_pad = (-max_pairs) % bl
    total = max_pairs + t_pad
    grid = (total // bl,)
    offs_p = _pad_lanes(offs, _PAD_OFF)
    counts_p = _pad_lanes(counts, 0)
    starts_p = _pad_lanes(starts, 0)
    perm_s_p = _pad_lanes(perm_s, 0)
    perm_u_p = _pad_lanes(perm_u, 0)

    full = lambda arr: pl.BlockSpec(arr.shape, lambda i: (0, 0))
    s_out, u_out = pl.pallas_call(
        functools.partial(_emit_kernel, n=n, m=m, block=bl),
        grid=grid,
        in_specs=[full(offs_p), full(counts_p), full(starts_p),
                  full(perm_s_p), full(perm_u_p)],
        out_specs=(pl.BlockSpec((1, bl), lambda i: (0, i)),
                   pl.BlockSpec((1, bl), lambda i: (0, i))),
        out_shape=(jax.ShapeDtypeStruct((1, total), jnp.int32),
                   jax.ShapeDtypeStruct((1, total), jnp.int32)),
        interpret=interpret,
    )(offs_p, counts_p, starts_p, perm_s_p, perm_u_p)
    return jnp.stack([s_out[0, :max_pairs], u_out[0, :max_pairs]], axis=1)


# ---------------------------------------------------------------------------
# streaming kernel — tables tiled through a double-buffered VMEM window
# ---------------------------------------------------------------------------

def _emit_stream_kernel(base_ref, tab_ref, perm_s_ref, perm_u_ref,
                        s_out_ref, u_out_ref, win_ref, sem_ref, *,
                        n: int, m: int, block: int, win: int):
    """One output tile per program; emitter tables stream in by DMA.

    ``base_ref`` (scalar prefetch) holds each tile's 128-aligned base
    index into the packed compacted table ``tab_ref`` (HBM-resident,
    rows: offsets / counts / starts / original emitter id).  ``win_ref``
    is the (2, 8, win) double-buffer scratch; while tile ``i`` computes
    out of one slot, tile ``i+1``'s window copies into the other.
    """
    i = pl.program_id(0)
    nt = pl.num_programs(0)
    slot = jax.lax.rem(i, 2)
    nxt = jax.lax.rem(i + 1, 2)

    def tile_copy(tile, s):
        return pltpu.make_async_copy(
            tab_ref.at[:, pl.ds(base_ref[tile], win)],
            win_ref.at[s], sem_ref.at[s])

    @pl.when(i == 0)
    def _():
        tile_copy(0, 0).start()

    @pl.when(i + 1 < nt)
    def _():
        tile_copy(i + 1, nxt).start()

    tile_copy(i, slot).wait()

    window = win_ref[slot]            # (8, win) int32
    offs_w = window[0, :]
    t = _block_slots(i, block)
    # the window covers every emitter this tile's slots can select
    # (compacted offsets are strictly increasing below saturation), so
    # the local search equals the global one wherever a slot is valid.
    k = _search_last_le(offs_w, t, win)
    j = t - jnp.take(offs_w, k)
    cnt = jnp.take(window[1, :], k)
    start = jnp.take(window[2, :], k)
    e = jnp.take(window[3, :], k)
    s_idx, u_idx = _pair_halves(e, j, start, cnt, perm_s_ref,
                                perm_u_ref, n=n, m=m)
    s_out_ref[0, :] = s_idx
    u_out_ref[0, :] = u_idx


@functools.partial(jax.jit,
                   static_argnames=("n", "m", "max_pairs", "block",
                                    "interpret"))
def twopass_emit_streaming(offs, counts, starts, perm_s, perm_u, *,
                           n: int, m: int, max_pairs: int,
                           block: int = DEF_BLOCK,
                           interpret: bool = False):
    """Streaming pass-2 pair write — bit-identical to ``twopass_emit``.

    XLA-side prep: compact the emitter tables to non-zero counts (so
    one output tile spans <= block + 1 consecutive entries), pack them
    into one (8, E_pad) int32 array that stays in HBM, and compute each
    tile's aligned window base with one vectorized searchsorted.  The
    kernel then double-buffers (8, block + 256) windows through VMEM.
    """
    if max_pairs == 0:
        return _empty_pairs()
    E = n + m
    # lane-multiple tile (the DMA window slice must be 128-aligned)
    bl = min(lane_pad(block), max(128, lane_pad(max_pairs)))
    win = stream_window(bl)
    t_pad = (-max_pairs) % bl
    total = max_pairs + t_pad
    nt = total // bl

    tab = pack_emitter_tables(offs, counts, starts, n=n, m=m, min_len=win)
    e_pad = tab.shape[1]
    c_offs = tab[0]

    t0 = jnp.arange(nt, dtype=jnp.int32) * bl
    k0 = jnp.searchsorted(c_offs, t0, side="right").astype(jnp.int32) - 1
    base = (jnp.maximum(k0, 0) // 128) * 128
    base = jnp.minimum(base, e_pad - win)

    perm_s_p = _pad_lanes(perm_s, 0)
    perm_u_p = _pad_lanes(perm_u, 0)

    full = lambda arr: pl.BlockSpec(arr.shape, lambda i, b: (0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
                  full(perm_s_p), full(perm_u_p)],
        out_specs=(pl.BlockSpec((1, bl), lambda i, b: (0, i)),
                   pl.BlockSpec((1, bl), lambda i, b: (0, i))),
        scratch_shapes=[pltpu.VMEM((2, 8, win), jnp.int32),
                        pltpu.SemaphoreType.DMA((2,))],
    )
    s_out, u_out = pl.pallas_call(
        functools.partial(_emit_stream_kernel, n=n, m=m, block=bl,
                          win=win),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((1, total), jnp.int32),
                   jax.ShapeDtypeStruct((1, total), jnp.int32)),
        interpret=interpret,
    )(base, tab, perm_s_p, perm_u_p)
    return jnp.stack([s_out[0, :max_pairs], u_out[0, :max_pairs]], axis=1)


# ---------------------------------------------------------------------------
# CSR decode kernel — constant VMEM; permutation runs stream in by DMA
# ---------------------------------------------------------------------------

def _scalar_at(vec, idx):
    """vec[idx] as a traced scalar (dynamic index into a loaded vector)."""
    return jax.lax.dynamic_slice(vec, (idx,), (1,))[0]


def _csr_decode_kernel(meta_ref, tab_ref, perm_s_ref, perm_u_ref,
                       s_out_ref, u_out_ref, tab_win_ref, run_ref,
                       sem_ref, *, n: int, m: int, block: int, win: int,
                       run: int):
    """Decode one tile of pair slots from the CSR form.

    ``meta_ref`` (scalar prefetch): slot 0 is the decode window's first
    global slot id ``w0`` (dynamic — one compile covers every window
    offset of a given size), slots 1.. are each tile's 128-aligned base
    into the packed table.  ``tab_ref`` / ``perm_s_ref`` / ``perm_u_ref``
    stay in HBM (``ANY``); per tile the kernel copies one (8, win)
    table window in, binary-searches the owning emitter per lane, then
    issues one fixed-``run``-length DMA per selected emitter, landing
    the permutation runs at slot-relative positions in the ``run_ref``
    scratch line.  Copies go in ascending emitter order: slot ``p``'s
    owner is the *last* emitter whose run covers ``p``, so its copy is
    the final write there and any earlier run's overhang is dead.
    """
    i = pl.program_id(0)
    tab_cp = pltpu.make_async_copy(
        tab_ref.at[:, pl.ds(meta_ref[1 + i], win)],
        tab_win_ref, sem_ref.at[0])
    tab_cp.start()
    tab_cp.wait()

    window = tab_win_ref[...]         # (8, win) int32
    offs_w = window[0, :]
    t0 = meta_ref[0] + i * block
    t = t0 + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)[0, :]
    k = _search_last_le(offs_w, t, win)
    j = t - jnp.take(offs_w, k)
    cnt = jnp.take(window[1, :], k)
    e = jnp.take(window[3, :], k)

    # every lane's selection lies in [k_lo, k_hi]; the range is all
    # real emitters (pads sit past every selectable entry), so the
    # class split below never sees the n+m sentinel.
    k_lo = jnp.min(k)
    n_runs = jnp.max(k) - k_lo + 1

    def copy_run(src_ref, src0, p0):
        cp = pltpu.make_async_copy(
            src_ref.at[0, pl.ds(src0, run)],
            run_ref.at[0, pl.ds(p0, run)], sem_ref.at[1])
        cp.start()
        cp.wait()

    def body(r, carry):
        kk = k_lo + r
        off_r = _scalar_at(offs_w, kk)
        cnt_r = _scalar_at(window[1, :], kk)
        start_r = _scalar_at(window[2, :], kk)
        e_r = _scalar_at(window[3, :], kk)
        # first rank this tile needs from emitter kk, clamped to its
        # count: start + j0 <= start + count stays inside the real
        # permutation (class A: aA + cnt_a = rank_hi <= m, and
        # symmetrically for class B), so the fixed-length over-read
        # lands in pad_perm_for_runs's tail padding.
        j0 = jnp.clip(t0 - off_r, 0, cnt_r)
        p0 = jnp.maximum(off_r - t0, 0)   # slot-relative landing spot
        src0 = start_r + j0

        @pl.when(e_r < n)
        def _():
            copy_run(perm_u_ref, src0, p0)

        @pl.when(e_r >= n)
        def _():
            copy_run(perm_s_ref, src0, p0)

        return carry

    jax.lax.fori_loop(0, n_runs, body, 0)

    v = run_ref[0, pl.ds(0, block)]
    valid = (j >= 0) & (j < cnt)
    is_a = e < n
    s_out_ref[0, :] = jnp.where(valid, jnp.where(is_a, e, v), -1)
    u_out_ref[0, :] = jnp.where(valid, jnp.where(is_a, v, e - n), -1)


@functools.partial(jax.jit,
                   static_argnames=("n", "m", "nslots", "block",
                                    "interpret"))
def csr_decode_window(tab, perm_s_pad, perm_u_pad, w0, *, n: int, m: int,
                      nslots: int, block: int = DEF_BLOCK,
                      interpret: bool = False):
    """Decode ``nslots`` pair slots starting at dynamic slot ``w0``.

    ``tab`` is the packed compacted emitter table from
    ``pack_emitter_tables`` (built with ``min_len >=
    stream_window(lane_pad(block))``), ``perm_s_pad`` / ``perm_u_pad``
    the permutations padded by ``pad_perm_for_runs``.  Returns the
    (nslots, 2) int32 slots ``[w0, w0 + nslots)`` of the dense pass-2
    buffer, bit-identical to ``core.sbm._twopass_emit`` on that window
    (slots at or past the emit capacity decode to the −1 pad — callers
    must trim to the capacity themselves; see ``kernels.ops.CSRPairs``).
    ``w0`` is a traced operand: decoding a different window of the same
    size never retraces.
    """
    if nslots == 0:
        return _empty_pairs()
    e_pad = tab.shape[1]
    bl = min(lane_pad(block), max(128, lane_pad(nslots)))
    win = stream_window(bl)
    run = bl
    if e_pad < win:
        raise ValueError(
            f"packed table length {e_pad} is narrower than the decode "
            f"window {win}; pack with min_len >= stream_window("
            f"lane_pad(block)) (block={block})")
    t_pad = (-nslots) % bl
    total = nslots + t_pad
    nt = total // bl

    w0 = jnp.asarray(w0, jnp.int32)
    t0s = w0 + jnp.arange(nt, dtype=jnp.int32) * bl
    k0 = jnp.searchsorted(tab[0], t0s, side="right").astype(jnp.int32) - 1
    base = (jnp.maximum(k0, 0) // 128) * 128
    base = jnp.clip(base, 0, e_pad - win)
    meta = jnp.concatenate([jnp.reshape(w0, (1,)), base])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)] * 3,
        out_specs=(pl.BlockSpec((1, bl), lambda i, mref: (0, i)),
                   pl.BlockSpec((1, bl), lambda i, mref: (0, i))),
        scratch_shapes=[pltpu.VMEM((8, win), jnp.int32),
                        pltpu.VMEM((1, bl + run), jnp.int32),
                        pltpu.SemaphoreType.DMA((2,))],
    )
    s_out, u_out = pl.pallas_call(
        functools.partial(_csr_decode_kernel, n=n, m=m, block=bl,
                          win=win, run=run),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((1, total), jnp.int32),
                   jax.ShapeDtypeStruct((1, total), jnp.int32)),
        interpret=interpret,
    )(meta, tab, perm_s_pad, perm_u_pad)
    return jnp.stack([s_out[0, :nslots], u_out[0, :nslots]], axis=1)
