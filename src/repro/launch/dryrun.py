import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements in this module: jax
locks the device count at first init, and only the dry-run fakes 512
host devices (tests/benches keep the real single device).

Per cell this emits a JSON record with:
  - compiled.memory_analysis()  (per-device bytes: args/temp/output)
  - compiled.cost_analysis()    (per-device HLO FLOPs + bytes accessed)
  - the collective schedule parsed from post-SPMD HLO (op type, result
    bytes, group size, estimated per-device link bytes)
  - the three §Roofline terms for TPU v5e constants
Failures (sharding mismatch, OOM-at-compile, unsupported collective) are
system bugs per the brief — surfaced, not swallowed.
"""  # noqa: E402
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import (ALIASES, ARCHS, SHAPES, get_config,
                           get_smoke_config, shape_applicable)
from repro.launch import partition as pt
from repro.launch.mesh import (compat_make_mesh, make_production_mesh,
                               mesh_context)
from repro.launch.steps import (abstract_cache, abstract_opt,
                                abstract_params, input_structs,
                                make_decode_step, make_prefill_step,
                                make_train_step)
from repro.optim import AdamWConfig

# --- TPU v5e roofline constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # B/s
LINK_BW = 50e9           # B/s per ICI link

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
                "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter"
    r"|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"(pred|[sufbc]\d?\d+)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo: str, n_devices: int):
    """Collective schedule: per-op result bytes + est. link bytes/device."""
    out = []
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result, op = m.group(1), m.group(2)
        rb = _shape_bytes(result)
        gm = _GROUP_IOTA_RE.search(line)
        if gm:
            p = int(gm.group(2))
        else:
            gm2 = _GROUP_RE.search(line)
            p = len(gm2.group(1).split(",")) if gm2 else n_devices
        p = max(p, 2)
        if op == "all-gather":
            link = rb * (p - 1) / p
        elif op == "reduce-scatter":
            link = rb * (p - 1)            # result is the scattered shape
        elif op == "all-reduce":
            link = 2 * rb * (p - 1) / p
        elif op == "all-to-all":
            link = rb * (p - 1) / p
        else:                               # collective-permute
            link = rb
        out.append({"op": op, "result_bytes": rb, "group": p,
                    "link_bytes": link})
    return out


def _probe_layers(cfg):
    """(l1_cfg, l2_cfg, var_layers_in_l1, full_var_layers) for the
    unrolled cost probes.  XLA's cost_analysis counts while-loop bodies
    once, so per-layer FLOPs/bytes/collectives are measured by compiling
    unrolled 1- and 2-variable-layer models and differencing; totals are
    extrapolated linearly (exact: layers are homogeneous by
    construction)."""
    f = {"unroll_layers": True, "q_chunk": 1 << 30, "remat": cfg.remat}
    if cfg.family == "moe":
        nd = cfg.first_dense_layers
        c1 = dataclasses.replace(cfg, n_layers=nd + 1, **f)
        c2 = dataclasses.replace(cfg, n_layers=nd + 2, **f)
        return c1, c2, 1, cfg.n_layers - nd
    if cfg.family == "hybrid":
        per = cfg.attn_every
        c1 = dataclasses.replace(cfg, n_layers=per, **f)
        c2 = dataclasses.replace(cfg, n_layers=2 * per, **f)
        return c1, c2, 1, cfg.n_layers // per
    if cfg.family == "audio":
        c1 = dataclasses.replace(cfg, n_layers=1, enc_layers=1, **f)
        c2 = dataclasses.replace(cfg, n_layers=2, enc_layers=2, **f)
        return c1, c2, 1, cfg.n_layers
    c1 = dataclasses.replace(cfg, n_layers=1, **f)
    c2 = dataclasses.replace(cfg, n_layers=2, **f)
    return c1, c2, 1, cfg.n_layers


def _compile_cell(cfg, spec, mesh):
    """Lower + compile one cell; returns (compiled, n_devices)."""
    pstruct = abstract_params(cfg)
    pspecs = pt.sanitize_tree(mesh, pt.param_specs(pstruct), pstruct)
    batch_struct = input_structs(cfg, spec)
    bspecs = pt.sanitize_tree(mesh, pt.batch_specs(mesh, batch_struct),
                              batch_struct)
    if spec.kind == "train":
        ostruct = abstract_opt(cfg)
        ospecs = pt.opt_specs(ostruct, pspecs)
        fn = make_train_step(cfg, AdamWConfig())
        in_specs = (pspecs, ospecs, bspecs)
        out_specs = (pspecs, ospecs,
                     jax.tree.map(lambda _: pt.P(),
                                  {"loss": 0, "ce": 0, "aux": 0,
                                   "grad_norm": 0, "lr": 0}))
        args = (pstruct, ostruct, batch_struct)
        donate = (0, 1)
    else:
        cstruct = abstract_cache(cfg, spec)
        seq_shard = spec.global_batch == 1
        cspecs = pt.sanitize_tree(
            mesh, pt.cache_specs(mesh, cstruct, batch=spec.global_batch,
                                 seq_shard=seq_shard), cstruct)
        if spec.kind == "prefill":
            fn = make_prefill_step(cfg)
        else:
            fn = make_decode_step(cfg)
        logits_spec = pt.P(pt.batch_dims(mesh)
                           if spec.global_batch > 1 else None, None)
        in_specs = (pspecs, cspecs, bspecs)
        out_specs = (logits_spec, cspecs)
        args = (pstruct, cstruct, batch_struct)
        donate = (1,)
    with mesh_context(mesh):
        jitted = jax.jit(fn,
                         in_shardings=pt.named(mesh, in_specs),
                         out_shardings=pt.named(mesh, out_specs),
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return compiled


_CONV_RE = re.compile(r"= f32\[([\d,]+)\][^=]*convert\(")


def bf16_ghost_bytes(hlo: str) -> int:
    """CPU-backend artifact: XLA CPU legalizes bf16 by upconversion and
    materializes whole-tensor f32 copies of large bf16 buffers (e.g. the
    layer-scan residual stack).  Verified absent from the jaxpr (the
    residual is bf16 at the JAX level) — a real TPU backend computes
    bf16 natively.  Count: f32 convert outputs ≥64 MiB whose exact shape
    also exists as a bf16 tensor.  Reported so the v5e memory estimate
    can be corrected (memory.peak_tpu_estimate)."""
    bf16_shapes = set(re.findall(r"bf16\[([\d,]+)\]", hlo))
    seen = {}
    for m in _CONV_RE.finditer(hlo):
        dims = m.group(1)
        if dims not in bf16_shapes:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 >= 64 * 1024 * 1024:
            seen[dims] = n * 4
    return int(sum(seen.values()))


def _cost_record(compiled, n_dev):
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older JAX wraps the dict per device
        ca = ca[0] if ca else {}
    colls = parse_collectives(compiled.as_text(), n_dev)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(sum(c["link_bytes"] for c in colls)),
        "colls": colls,
    }


def run_cell(arch: str, shape: str, multi_pod: bool,
             smoke: bool = False, overrides: dict | None = None) -> dict:
    spec = SHAPES[shape]
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    ok, why = shape_applicable(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    if smoke:  # selftest: tiny mesh, same axis names
        shape_ax = ((2, 2, 4), ("pod", "data", "model")) if multi_pod \
            else ((4, 4), ("data", "model"))
        mesh = compat_make_mesh(shape_ax[0], shape_ax[1])
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    t0 = time.time()

    # -- main compile (full model, scan-over-layers) ------------------------
    compiled = _compile_cell(cfg, spec, mesh)
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ghost = bf16_ghost_bytes(compiled.as_text())
    main_cost = _cost_record(compiled, n_dev)

    # -- cost probes: unrolled 1- and 2-layer compiles + extrapolation ------
    t1 = time.time()
    c1, c2, l1, l_full = _probe_layers(cfg)
    r1 = _cost_record(_compile_cell(c1, spec, mesh), n_dev)
    r2 = _cost_record(_compile_cell(c2, spec, mesh), n_dev)
    t_probe = time.time() - t1

    # grad-accum microbatch scan is itself a while loop counted once by
    # cost_analysis — scale costs back up by k (train cells only)
    k_accum = cfg.grad_accum if spec.kind == "train" else 1

    def extrap(key):
        per = (r2[key] - r1[key]) * k_accum
        return max(r1[key] * k_accum + (l_full - l1) * per, 0.0), per

    flops, flops_per_layer = extrap("flops")
    bytes_acc, _ = extrap("bytes")
    coll_bytes, coll_per_layer = extrap("coll_bytes")

    n_par = cfg.n_params()
    active = n_par
    if cfg.family == "moe":
        dead = (cfg.n_experts - cfg.top_k) * 3 * cfg.d_model * \
            cfg.moe_d_ff * (cfg.n_layers - cfg.first_dense_layers)
        active = n_par - dead
    tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode"
                                  else 1)
    mult = 6 if spec.kind == "train" else 2
    model_flops = mult * active * tokens / n_dev

    colls = main_cost["colls"]
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev, "status": "ok",
        "compile_s": round(t_compile, 1), "probe_s": round(t_probe, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate": (ma.argument_size_in_bytes
                              + ma.temp_size_in_bytes
                              + ma.output_size_in_bytes
                              - ma.alias_size_in_bytes),
            "cpu_bf16_ghost_bytes": ghost,
            # clamped at the argument-residency floor: the ghost detector
            # can over-count when an f32 convert output aliases/fuses
            "peak_tpu_estimate": max(
                ma.argument_size_in_bytes + ma.temp_size_in_bytes
                + ma.output_size_in_bytes - ma.alias_size_in_bytes
                - ghost,
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                - ma.alias_size_in_bytes),
        },
        "cost": {
            "flops_per_device": flops,
            "bytes_accessed_per_device": bytes_acc,
            "flops_per_layer": flops_per_layer,
            "raw_scan_flops_per_device": main_cost["flops"],
            "probe_note": ("flops/bytes/collectives extrapolated from "
                           "unrolled 1/2-layer probe compiles (XLA cost "
                           "analysis counts while-loop bodies once)"),
        },
        "collectives": {
            "count": len(colls),
            "by_op": {op: int(sum(1 for c in colls if c["op"] == op))
                      for op in set(c["op"] for c in colls)},
            "link_bytes_per_device": coll_bytes,
            "link_bytes_per_layer": coll_per_layer,
            "schedule_sample": colls[:40],
        },
        "roofline": {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll_bytes / LINK_BW,
            "model_flops_per_device": model_flops,
            "useful_flops_ratio": (model_flops / flops) if flops else None,
        },
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: rec["roofline"][k])
    rec["roofline"]["dominant"] = dom
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--smoke", action="store_true",
                    help="use reduced configs (selftest)")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (perf variants), "
                         "e.g. --override mla_absorb=False")
    ap.add_argument("--tag", default="",
                    help="suffix for output filenames (variants)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        overrides[k] = {"True": True, "False": False}.get(
            v, int(v) if v.lstrip("-").isdigit() else v)

    archs = ARCHS if args.arch == "all" else [
        ALIASES.get(args.arch, args.arch)]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                if args.tag:
                    tag += f"_{args.tag}"
                path = outdir / f"{tag}.json"
                if path.exists():
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[run] {tag}", flush=True)
                try:
                    rec = run_cell(arch, shape, mp, smoke=args.smoke,
                                   overrides=overrides)
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-4000:]}
                path.write_text(json.dumps(rec, indent=1))
                st = rec.get("status")
                extra = ""
                if st == "ok":
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']}"
                             f" c={r['compute_s']:.2e}"
                             f" m={r['memory_s']:.2e}"
                             f" n={r['collective_s']:.2e}"
                             f" compile={rec['compile_s']}s")
                print(f"[done] {tag}: {st}{extra}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
