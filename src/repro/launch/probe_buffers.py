import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))
"""Hillclimb tool: compile one cell and print the largest per-device
HLO tensors (who is eating the memory budget)."""  # noqa: E402
import argparse
import re

import numpy as np

from repro.configs import ALIASES, SHAPES, get_config
from repro.launch.dryrun import _compile_cell, _DTYPE_BYTES
from repro.launch.mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--top", type=int, default=14)
    args = ap.parse_args()

    cfg = get_config(ALIASES.get(args.arch, args.arch))
    spec = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi)
    compiled = _compile_cell(cfg, spec, mesh)
    ma = compiled.memory_analysis()
    print(f"peak ~ {(ma.argument_size_in_bytes + ma.temp_size_in_bytes + ma.output_size_in_bytes - ma.alias_size_in_bytes) / 1e9:.2f} GB "
          f"(args {ma.argument_size_in_bytes/1e9:.2f} temp "
          f"{ma.temp_size_in_bytes/1e9:.2f} out "
          f"{ma.output_size_in_bytes/1e9:.2f} alias "
          f"{ma.alias_size_in_bytes/1e9:.2f})")
    sizes = {}
    for m in re.finditer(r"(pred|[sufbc]\d?\d+)\[([\d,]+)\]",
                         compiled.as_text()):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * _DTYPE_BYTES.get(dt, 4)
        key = f"{dt}[{dims}]"
        sizes[key] = b
    for k, v in sorted(sizes.items(), key=lambda kv: -kv[1])[: args.top]:
        print(f"{v/1e9:9.2f} GB  {k}")


if __name__ == "__main__":
    main()
