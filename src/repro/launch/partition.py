"""Sharding rules: param/optimizer/batch/cache PartitionSpecs.

GSPMD annotations for the production mesh (DESIGN.md §5):

  batch        → ('pod','data')  (DP across pods + in-pod data axis)
  d_model dim  → 'data'          (FSDP / ZeRO-3: per-layer all-gather
                                  inside the layer scan)
  heads / d_ff / vocab / experts → 'model'  (TP / EP)
  KV-cache sequence (long_500k, batch=1) → 'data'  (SP)

Rules are matched on param-tree path suffixes; stacked leading layer
axes are padded with None.  Optimizer state mirrors the param specs
(m/v shard exactly like their parameter), so optimizer sharding is
ZeRO-style by construction.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

def _rule_table():
    """(path-suffix tokens, spec for trailing dims).  DP = FSDP axis
    ('data'); MP = tensor axis ('model')."""
    MP, DP = "model", "data"
    return [
        # embeddings / unembeddings
        (("embed", "table"), (MP, DP)),
        (("lm_head", "w"), (DP, MP)),
        (("enc_pos",), (None, DP)),
        # attention projections (d, heads*dh) / (heads*dh, d)
        (("attn", "wq", "w"), (DP, MP)),
        (("attn", "wk", "w"), (DP, MP)),
        (("attn", "wv", "w"), (DP, MP)),
        (("attn", "wo", "w"), (MP, DP)),
        (("xattn", "wq", "w"), (DP, MP)),
        (("xattn", "wk", "w"), (DP, MP)),
        (("xattn", "wv", "w"), (DP, MP)),
        (("xattn", "wo", "w"), (MP, DP)),
        (("wq", "b"), (MP,)),
        (("wk", "b"), (MP,)),
        (("wv", "b"), (MP,)),
        # MLA
        (("w_dkv", "w"), (DP, None)),
        (("w_ukv", "w"), (None, MP)),
        (("w_dq", "w"), (DP, None)),
        (("w_uq", "w"), (None, MP)),
        (("attn", "wq", "w"), (DP, MP)),
        # dense mlp
        (("w_gate", "w"), (DP, MP)),
        (("w_up", "w"), (DP, MP)),
        (("w_down", "w"), (MP, DP)),
        # moe experts (E, d, f) / (E, f, d); router small -> replicated
        (("moe", "w_gate"), (MP, DP, None)),
        (("moe", "w_up"), (MP, DP, None)),
        (("moe", "w_down"), (MP, None, DP)),
        (("router", "w"), (DP, None)),
        # mamba2
        (("in_proj", "w"), (DP, MP)),
        (("out_proj", "w"), (MP, DP)),
        (("conv_w",), (None, MP)),
        (("conv_b",), (MP,)),
        (("mixer", "norm", "scale"), (MP,)),
    ]


def _path_tokens(path) -> tuple[str, ...]:
    toks = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            toks.append(str(e.key))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            toks.append(str(e.name))
    return tuple(toks)


def spec_for_param(path, leaf) -> P:
    toks = _path_tokens(path)
    for suffix, dims in _rule_table():
        if toks[-len(suffix):] == tuple(suffix):
            pad = leaf.ndim - len(dims)
            if pad < 0:
                continue
            return P(*((None,) * pad + tuple(dims)))
    return P()  # replicate (norm scales, small vectors, A_log, ...)


def param_specs(params) -> object:
    return jax.tree_util.tree_map_with_path(spec_for_param, params)


def opt_specs(opt_state, pspecs) -> object:
    return {"m": pspecs, "v": pspecs, "step": P()}


def batch_dims(mesh: Mesh) -> tuple:
    """Data-parallel mesh axes for the batch dim."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_specs(mesh: Mesh, batch_example: dict, *, shard_batch=True):
    dp = batch_dims(mesh) if shard_batch else ()

    def one(path, leaf):
        if leaf.ndim == 0:
            return P()
        lead = dp if (dp and leaf.shape[0] > 1) else None
        return P(lead, *((None,) * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(one, batch_example)


def cache_specs(mesh: Mesh, cache_example, *, batch: int,
                seq_shard: bool) -> object:
    """KV/SSM cache specs.

    Normal decode/prefill: batch over ('pod','data'); KV heads over
    'model' when divisible, otherwise the cache *sequence* shards over
    'model' (the serving-stack convention for kv_heads < tp — attention
    then reduces over a sequence-sharded context, which XLA lowers to a
    partial-softmax + all-reduce pattern).
    long_500k (batch=1): sequence over 'data' (SP), heads over 'model'.
    """
    dp = batch_dims(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msz = sizes.get("model", 1)

    def one(path, leaf):
        toks = _path_tokens(path)
        nd = leaf.ndim
        name = toks[-1] if toks else ""
        # leading stacked layer/group axes padded with None
        if name in ("k", "v"):          # (..., B, Smax, H, dh)
            lead = (None,) * (nd - 4)
            n_heads = leaf.shape[-2]
            if seq_shard:
                return P(*lead, None, "data", "model", None)
            if n_heads % msz == 0:
                return P(*lead, dp, None, "model", None)
            return P(*lead, dp, "model", None, None)   # seq over tp
        if name in ("ckv", "krope"):    # (..., B, Smax, feat)
            lead = (None,) * (nd - 3)
            feat = leaf.shape[-1]
            tp_feat = "model" if feat % msz == 0 else None
            if seq_shard:
                return P(*lead, None, "data", tp_feat)
            if tp_feat:
                return P(*lead, dp, None, tp_feat)
            return P(*lead, dp, "model", None)
        if name == "ssm":               # (..., B, nh, hd, ns)
            lead = (None,) * (nd - 4)
            tp_h = "model" if leaf.shape[-3] % msz == 0 else None
            bdim = None if seq_shard else dp
            return P(*lead, bdim, tp_h, None, None)
        if name == "conv":              # (..., B, W-1, C)
            lead = (None,) * (nd - 3)
            tp_c = "model" if leaf.shape[-1] % msz == 0 else None
            bdim = None if seq_shard else dp
            return P(*lead, bdim, None, tp_c)
        if name == "enc_out":           # (B, F, d)
            tp_d = "model" if leaf.shape[-1] % msz == 0 else None
            return P(None if seq_shard else dp, None, tp_d)
        return P()

    return jax.tree_util.tree_map_with_path(one, cache_example)


def sanitize(mesh: Mesh, spec: P, shape) -> P:
    """Drop axis names whose size does not divide the dimension.

    jit argument shardings require even tiling; e.g. 2 KV heads cannot
    shard over a 16-way 'model' axis — such dims fall back to replicated
    (the Megatron convention for kv_heads < tp).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for d, n in zip(dims, shape):
        if d is None:
            out.append(None)
            continue
        axes = d if isinstance(d, tuple) else (d,)
        total = int(np.prod([sizes[a] for a in axes]))
        out.append(d if n % total == 0 else None)
    return P(*out)


def sanitize_tree(mesh: Mesh, spec_tree, struct_tree):
    return jax.tree.map(
        lambda s, x: sanitize(mesh, s, x.shape), spec_tree, struct_tree,
        is_leaf=lambda x: isinstance(x, P))


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
