"""Deprecated module path — the LM demo moved to ``repro.launch.lm_serve``.

``repro.serve`` is the DDM serving subsystem (multi-tenant
``DDMServer``); this LM prefill/decode launcher now lives at
``repro.launch.lm_serve`` so the two cannot be confused.  This stub
forwards (one ``DeprecationWarning``, attributed to the importer) and
keeps ``python -m repro.launch.serve`` working.
"""
from __future__ import annotations

import warnings

from .lm_serve import main

__all__ = ["main"]

warnings.warn(
    "repro.launch.serve has moved to repro.launch.lm_serve "
    "(repro.serve is the DDM serving layer); update the import",
    DeprecationWarning, stacklevel=2)

if __name__ == "__main__":
    main()
