"""Step builders: train / prefill / decode closures + abstract inputs.

``input_structs`` returns ShapeDtypeStruct stand-ins for every model
input of an (arch × shape) cell — weak-type-correct, shardable, no
device allocation (the dry-run contract).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import ShapeSpec
from ..models import transformer as T
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init, adamw_update


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_structs(cfg: ModelConfig, spec: ShapeSpec) -> dict:
    """Data inputs (tokens etc.) for one cell, as ShapeDtypeStructs."""
    B = spec.global_batch
    if spec.kind == "train":
        batch = {"tokens": sds((B, spec.seq_len + 1), jnp.int32)}
        if cfg.family == "audio":
            batch["frames"] = sds((B, cfg.enc_frames, cfg.d_model),
                                  jnp.bfloat16)
        return batch
    if spec.kind == "prefill":
        batch = {"tokens": sds((B, spec.seq_len), jnp.int32)}
        if cfg.family == "audio":
            batch["frames"] = sds((B, cfg.enc_frames, cfg.d_model),
                                  jnp.bfloat16)
        return batch
    # decode: one new token against a cache of seq_len
    return {"tokens": sds((B, 1), jnp.int32),
            "cur_len": sds((), jnp.int32)}


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: T.init_params(cfg, k), jax.random.PRNGKey(0))


def abstract_opt(cfg: ModelConfig):
    params = abstract_params(cfg)
    return jax.eval_shape(adamw_init, params)


def abstract_cache(cfg: ModelConfig, spec: ShapeSpec):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, spec.global_batch, spec.seq_len))


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    """Train step with optional gradient accumulation
    (``cfg.grad_accum`` microbatches scanned sequentially — activation
    memory ÷ k at the cost of k smaller matmuls; the optimizer update
    sees the mean gradient, so semantics match the monolithic batch)."""
    k = max(cfg.grad_accum, 1)

    def grad_of(params, batch):
        return jax.value_and_grad(
            lambda p: T.loss_fn(p, batch, cfg), has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if k == 1:
            (loss, metrics), grads = grad_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape((k, a.shape[0] // k) + a.shape[1:]),
                batch)

            def body(acc, mbatch):
                (l, m), g = grad_of(params, mbatch)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / k, acc, g)
                return acc, (l, m)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, ms) = jax.lax.scan(body, zeros, mb)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, ms)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        out = {"loss": loss, **metrics, **opt_metrics}
        return params, opt_state, out

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, cache, batch):
        logits, cache = T.prefill(params, batch["tokens"], cfg, cache,
                                  frames=batch.get("frames"))
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, batch):
        logits, cache = T.decode_step(params, batch["tokens"], cfg,
                                      cache, batch["cur_len"])
        return logits, cache

    return decode_step
