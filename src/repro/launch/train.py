"""Training launcher.

Single-host entry point driving the fault-tolerant runtime; the same
step function lowers onto the production mesh via dryrun.py (this
launcher is what a per-host bootstrap would exec under
``jax.distributed.initialize`` on a real cluster — documented in
DESIGN.md §5).

Example (reduced config, CPU):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --smoke --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

from repro.configs import ALIASES, get_config, get_smoke_config
from repro.data.pipeline import DataConfig
from repro.optim import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-shards", type=int, default=1)
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (FT drill)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else \
        get_config(args.arch)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                       total_steps=args.steps)
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every,
                         n_ckpt_shards=args.ckpt_shards,
                         async_ckpt=args.async_ckpt)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    tr = Trainer(cfg, ocfg, tcfg, dcfg)

    t0 = time.time()
    toks = args.batch * args.seq

    def log(step, m):
        if step % 10 == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e} gnorm "
                  f"{float(m['grad_norm']):.2f} "
                  f"({toks * (step + 1) / max(dt, 1e-9):.0f} tok/s)",
                  flush=True)

    failures = (args.fail_at,) if args.fail_at is not None else ()
    params, _, metrics = tr.run_resilient(args.steps, failures=failures,
                                          on_step=log)
    print(f"final loss {float(metrics['loss']):.4f} "
          f"wall {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
