"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — required because only dryrun.py fakes
the device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model_axis: int = 1):
    """Whatever this host has — used by tests/examples, not dry-runs."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh(
        (data, model_axis), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
