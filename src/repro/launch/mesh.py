"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — required because only dryrun.py fakes
the device count.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where the installed
    JAX supports them.

    ``jax.sharding.AxisType`` (and the ``axis_types`` kwarg) only exist on
    newer JAX; on older versions every mesh axis is Auto by default, so
    falling back to a plain ``make_mesh`` is semantically identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:  # AxisType exists but make_mesh predates kwarg
            pass
    return jax.make_mesh(shape, axes)


def mesh_context(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` is the new-JAX spelling; older versions use the
    ``Mesh`` object's own context manager (the ambient *physical* mesh),
    which the sharding-constraint resolution in ``models.sharding``
    reads through its matching fallback.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever this host has — used by tests/examples, not dry-runs."""
    n = len(jax.devices())
    data = n // model_axis
    return compat_make_mesh((data, model_axis), ("data", "model"))
