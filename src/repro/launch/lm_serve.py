"""LM serving launcher: prefill a batch of prompts, decode with KV caches.

Demonstrates the same prefill/decode step functions the dry-run lowers
onto the production mesh, including the DDM-planned sliding-window read
for ``attn_pattern=ddm_window`` archs.  (Formerly ``repro.launch.serve``
— renamed so ``repro.serve`` unambiguously means the DDM serving
subsystem.)

Example:
    PYTHONPATH=src python -m repro.launch.lm_serve --arch zamba2-2.7b \
        --smoke --batch 4 --prompt-len 48 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else \
        get_config(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    B = args.batch
    max_len = args.prompt_len + args.gen + 1
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       (B, args.prompt_len)), jnp.int32)
    frames = None
    if cfg.family == "audio":
        frames = jnp.asarray(
            0.1 * rng.normal(size=(B, cfg.enc_frames, cfg.d_model)),
            jnp.bfloat16)

    cache = T.init_cache(cfg, B, max_len)
    prefill = jax.jit(lambda p, t, c, f: T.prefill(p, t, cfg, c,
                                                   frames=f))
    step = jax.jit(lambda p, t, c, i: T.decode_step(p, t, cfg, c, i))

    t0 = time.time()
    logits, cache = prefill(params, prompts, cache, frames)
    logits.block_until_ready()
    t_pre = time.time() - t0

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = step(params, tok, cache,
                             jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={cfg.name} pattern={cfg.attn_pattern}")
    print(f"prefill: {B}x{args.prompt_len} tokens in {t_pre:.2f}s "
          f"({B * args.prompt_len / max(t_pre, 1e-9):.0f} tok/s)")
    print(f"decode:  {B}x{args.gen} tokens in {t_dec:.2f}s "
          f"({B * args.gen / max(t_dec, 1e-9):.1f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
