"""repro: Parallel DDM (Marzolla & D'Angelo, TOMACS 2019) as a TPU-native JAX framework."""
__version__ = "0.1.0"
