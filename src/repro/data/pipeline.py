"""Deterministic synthetic token pipeline, host-sharded.

Design for 1000+ nodes (DESIGN.md §5): the batch for (step, host) is a
pure function of (seed, step, host) — no coordinator, no state.  A host
that restarts (fault tolerance) or is replaced (straggler eviction)
regenerates exactly its shard; elastic re-scale just re-partitions the
host-index space.  This is the property real pipelines get from
deterministic samplers over an index space; the token source here is a
synthetic mixture (zipfian unigrams + periodic motifs) so the loss has
learnable structure for the end-to-end examples.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1


def _zipf_probs(vocab: int, a: float = 1.2):
    r = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / r ** a
    return p / p.sum()


class SyntheticTokens:
    """Iterator-style pipeline: ``batch(step, host)`` -> (B_host, S+1)."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self._probs = _zipf_probs(cfg.vocab)

    def batch(self, step: int, host: int = 0) -> np.ndarray:
        cfg = self.cfg
        bh = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host]))
        toks = rng.choice(cfg.vocab, size=(bh, cfg.seq_len + 1),
                          p=self._probs)
        # periodic motif: learnable second-order structure
        period = 7 + (step % 5)
        motif = rng.integers(0, cfg.vocab, size=(bh, 1))
        idx = np.arange(cfg.seq_len + 1)[None, :]
        mask = (idx % period) == (step % period)
        toks = np.where(mask, motif, toks)
        return toks.astype(np.int32)

    def global_batch(self, step: int) -> np.ndarray:
        return np.concatenate(
            [self.batch(step, h) for h in range(self.cfg.n_hosts)], axis=0)
