"""Whisper-medium — enc-dec; conv frontend stubbed (precomputed 1500-frame embeddings); assigned seq shapes apply to the decoder stream  [arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name='whisper-medium',
    family='audio',
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab=51865,
    enc_layers=24,
    enc_frames=1500,
    cross_attn=True,
)

SMOKE = ModelConfig(
    name='whisper-medium-smoke',
    family='audio',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=256,
    enc_layers=2,
    enc_frames=32,
    cross_attn=True,
)
