"""Llama-3.2-3B — small llama3, GQA kv=8  [hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name='llama3.2-3b',
    family='dense',
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=128256,
    rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name='llama3.2-3b-smoke',
    family='dense',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=160,
    vocab=256,
)
