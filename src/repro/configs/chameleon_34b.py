"""Chameleon-34B — early-fusion VLM: VQ image tokens share the text vocab (frontend stub supplies the fused token stream); qk-norm per the paper  [arXiv:2405.09818; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name='chameleon-34b',
    family='vlm',
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    grad_accum=2,
)

SMOKE = ModelConfig(
    name='chameleon-34b-smoke',
    family='vlm',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=192,
    vocab=512,
    qk_norm=True,
)
