"""Phi-3.5-MoE (42B, 6.6B active) — 16 experts top-2, GQA kv=8  [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name='phi3.5-moe-42b-a6.6b',
    family='moe',
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=6400,
    vocab=32064,
    n_experts=16,
    top_k=2,
    n_shared_experts=0,
    moe_d_ff=6400,
    first_dense_layers=0,
    grad_accum=2,
)

SMOKE = ModelConfig(
    name='phi3.5-moe-smoke',
    family='moe',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=256,
    n_experts=4,
    top_k=2,
    n_shared_experts=0,
    moe_d_ff=128,
    first_dense_layers=0,
    capacity_factor=16.0,
)
