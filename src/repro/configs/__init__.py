"""Assigned architecture configs + input shapes + reduced smoke configs.

``get_config(name)`` returns the exact published configuration;
``get_smoke_config(name)`` returns a tiny same-family config for CPU
tests; ``SHAPES`` defines the 4 assigned input shapes.
"""
from __future__ import annotations

import dataclasses
import importlib

ARCHS = (
    "qwen2_0_5b", "llama3_2_3b", "yi_9b", "qwen3_14b", "zamba2_2_7b",
    "deepseek_v2_236b", "phi3_5_moe_42b", "chameleon_34b", "mamba2_780m",
    "whisper_medium",
)

# canonical ids from the assignment table -> module names
ALIASES = {
    "qwen2-0.5b": "qwen2_0_5b",
    "llama3.2-3b": "llama3_2_3b",
    "yi-9b": "yi_9b",
    "qwen3-14b": "qwen3_14b",
    "zamba2-2.7b": "zamba2_2_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "chameleon-34b": "chameleon_34b",
    "mamba2-780m": "mamba2_780m",
    "whisper-medium": "whisper_medium",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def _module(name: str):
    name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke_config(name: str):
    return _module(name).SMOKE


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs; reason if skipped (DESIGN.md
    §Arch-applicability)."""
    cfg = get_config(name=arch)
    spec = SHAPES[shape]
    if shape == "long_500k":
        # needs sub-quadratic attention: ssm/hybrid run (O(1) state decode
        # or DDM-planned windowed attention); pure full-attention skip.
        if cfg.family in ("ssm", "hybrid"):
            return True, ""
        return False, ("pure full-attention arch: long_500k requires "
                       "sub-quadratic attention (DESIGN.md)")
    return True, ""
