"""Qwen2-0.5B — GQA (kv=2), QKV bias, tied embeddings  [arXiv:2407.10671; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name='qwen2-0.5b',
    family='dense',
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_head=64,
    d_ff=4864,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name='qwen2-0.5b-smoke',
    family='dense',
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=256,
    qkv_bias=True,
    tie_embeddings=True,
)
