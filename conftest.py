"""Repo-level pytest config.

The full tier-1 suite compiles thousands of distinct XLA executables in
one process; on CPU jaxlib this eventually segfaults inside
``backend.compile`` once enough live executables accumulate (the seed
suite crashes the same way at the same cumulative point).  Dropping
jit/pjit caches between test modules caps the number of live
executables and keeps the process healthy; plans retrace on next use,
which individual tests already tolerate (every ``no_retrace`` window
warms up inside its own test).
"""

import os

import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    import jax

    jax.clear_caches()


def pytest_runtest_setup(item):
    if not os.environ.get("REPRO_LOG_MAPS"):
        return
    try:
        maps = sum(1 for _ in open(f"/proc/{os.getpid()}/maps"))
        with open("/tmp/maps.log", "a") as fh:
            fh.write(f"{maps}\t{item.nodeid}\n")
    except Exception:
        pass
