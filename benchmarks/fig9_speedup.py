"""Fig. 9/10 — WCT of parallel {BFM, GBM, ITM, SBM} and the P-way
decomposition of parallel SBM.

Paper setting: N = 1e6, α = 100 (Fig. 9) and N = 1e8 (Fig. 10 — beyond
this host; we scale to the largest N that completes in CPU budget and
keep the α = 100 regime).  BFM is Θ(N²) and, as in the paper's Fig. 12
range, is measured at a smaller N with the quadratic extrapolation
reported in `derived`.

Speedup axis: one physical core ⇒ structural reproduction — the
P-segment SBM decomposition (Alg. 6/7) is timed per P and verified
bit-equal to serial; per-segment work balance (the quantity that sets
speedup on real silicon) is reported as derived data.
"""
from __future__ import annotations

import numpy as np

from repro.core import paper_workload
from repro.core.sbm import sbm_count_chunked, sbm_count_sweep
from repro.kernels.ops import sbm_count_pallas

from .common import bench, plan_for, row

N_MAIN = 1_000_000
N_BFM = 20_000
ALPHA = 100.0


def run():
    S, U = paper_workload(seed=42, n_total=N_MAIN, alpha=ALPHA)
    Sb, Ub = paper_workload(seed=42, n_total=N_BFM, alpha=ALPHA)

    counts = {}

    bfm_plan = plan_for(Sb, Ub, "bfm")
    t = bench(bfm_plan.count, Sb, Ub)
    scale = (N_MAIN / N_BFM) ** 2
    row("fig9/bfm_wct_n2e4", t,
        f"K={bfm_plan.count(Sb, Ub)};extrap_1e6_s={t*scale:.1f}")

    for algo, name, kw in (("gbm", "fig9/gbm_wct_1e6_3000cells",
                            dict(ncells=3000)),
                           ("itm", "fig9/itm_wct_1e6", {}),
                           ("sbm", "fig9/sbm_wct_1e6", {})):
        plan = plan_for(S, U, algo, **kw)
        t = bench(plan.count, S, U)
        counts[algo] = plan.count(S, U)
        row(name, t, f"K={counts[algo]}")

    t = bench(sbm_count_pallas, S, U, block=4096, interpret=True)
    counts["sbm_pallas"] = sbm_count_pallas(S, U, block=4096,
                                            interpret=True)
    row("fig9/sbm_pallas_interpret_wct_1e6", t,
        f"K={counts['sbm_pallas']}")

    assert len(set(counts.values())) == 1, counts
    k_ref = sbm_count_sweep(S, U)

    # P-way decomposition (structural speedup axis)
    for p in (1, 2, 4, 8, 16, 32):
        t = bench(sbm_count_chunked, S, U, p=p)
        k = sbm_count_chunked(S, U, p=p)
        assert k == k_ref
        seg = 2 * N_MAIN // p
        row(f"fig9/sbm_chunked_p{p}", t,
            f"bitexact=1;endpoints_per_segment={seg}")
