"""Roofline table formatter — reads experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
                                                 [--mesh single] [--md]
                                                 [--bench BENCH_*.json ...]

Per (arch × shape): the three §Roofline terms in seconds, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, and per-device peak memory.
``--bench`` instead formats one or more ``BENCH_*.json`` trajectory
records (the files ``benchmarks.run --out`` writes and CI uploads) as a
markdown table — the perf-trajectory view over engine timings.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ARCH_ORDER = ["qwen2_0_5b", "llama3_2_3b", "yi_9b", "qwen3_14b",
              "zamba2_2_7b", "deepseek_v2_236b", "phi3_5_moe_42b",
              "chameleon_34b", "mamba2_780m", "whisper_medium"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirpath: str, mesh: str):
    recs = {}
    for f in Path(dirpath).glob(f"*_{mesh}.json"):
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt(x, w=9):
    if x is None:
        return " " * w
    return f"{x:{w}.2e}"


def bench_table(paths) -> str:
    """Markdown table over BENCH_*.json trajectory records."""
    lines = ["| file | row | us/call | derived |",
             "|---|---|---|---|"]
    for p in paths:
        rec = json.loads(Path(p).read_text())
        meta = rec.get("meta", {})
        tag = f"{Path(p).name} (devices={meta.get('devices', '?')})"
        for name, r in sorted(rec.get("rows", {}).items()):
            lines.append(
                f"| {tag} | {name} | {r['us']:.1f} | {r['derived']} |")
    return "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi"])
    ap.add_argument("--md", action="store_true", help="markdown table")
    ap.add_argument("--bench", nargs="+", default=None,
                    metavar="BENCH_smoke.json",
                    help="format benchmark trajectory records instead")
    args = ap.parse_args()
    if args.bench:
        print(bench_table(args.bench), end="")
        return
    recs = load(args.dir, args.mesh)

    sep = " | " if args.md else "  "
    hdr = ["arch", "shape", "compute_s", "memory_s", "collect_s",
           "dominant", "useful", "peakGB", "roofline%"]
    if args.md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "|".join("---" for _ in hdr) + "|")
    else:
        print(("%-17s %-11s %9s %9s %9s %-10s %6s %7s %6s") % tuple(hdr))
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skipped":
                line = [arch, shape, "-", "-", "-", "skipped", "-", "-",
                        "-"]
            elif r["status"] == "error":
                line = [arch, shape, "-", "-", "-", "ERROR", "-", "-",
                        "-"]
            else:
                rf = r["roofline"]
                dom = rf["dominant"].replace("_s", "")
                terms = [rf["compute_s"], rf["memory_s"],
                         rf["collective_s"]]
                # roofline fraction: ideal compute time / achievable
                # step time (sum is pessimistic-no-overlap; max is
                # perfect-overlap — report vs max)
                frac = rf["compute_s"] / max(max(terms), 1e-30)
                peak = r["memory"].get("peak_tpu_estimate",
                                       r["memory"]["peak_estimate"])
                line = [arch, shape,
                        f"{terms[0]:.2e}", f"{terms[1]:.2e}",
                        f"{terms[2]:.2e}", dom,
                        f"{rf['useful_flops_ratio']:.2f}"
                        if rf.get("useful_flops_ratio") else "-",
                        f"{peak / 1e9:.2f}",
                        f"{100 * frac:.1f}"]
            if args.md:
                print("| " + " | ".join(str(x) for x in line) + " |")
            else:
                print("%-17s %-11s %9s %9s %9s %-10s %6s %7s %6s"
                      % tuple(line))


if __name__ == "__main__":
    main()
