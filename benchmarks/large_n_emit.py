"""Large-N emit-route sweep: resident vs streaming vs CSR vs XLA pass 2.

The paper's evaluation centers on the 1e6-region regime; this sweep
drives the two-pass pair enumeration through every emit route the
byte-budget policy allows at each size (``kernels.ops.choose_emit_route``:
resident tables → streamed tables → CSR compressed emit → XLA pass 2),
asserts the routes are bit-identical on decoded pairs, and times them.
On this CPU host the Pallas routes run in interpret mode, so their
absolute timings are trajectory-only signal; the XLA rows and the
cross-route parity asserts are the load-bearing part, and on a real TPU
the same module times the compiled kernels.

The CSR rows are the 1e7-regime story: past n+m ≈ 2e6 the streamed
tables no longer fit the VMEM budget, and the csr route's footprint is
constant in n+m (one table window + two scratch rows), so the sweep's
top sizes (5e6, 1e7) run csr + xla only.  ``emit_csr_decode_n{N}`` rows
time the lazy ``CSRPairs`` view's window decode separately from pass 1.

With pass 2 constant-VMEM under the csr route, pass 1's global XLA
sort is the dominant cost at 1e7+ — the ``emit_pass1_*`` rows time the
flat global-sort pass 1 (``ops._twopass_tables``) against the hybrid
grid-bucketed pass 1 (``ops._hsbm_tables``, ``algo="hsbm"``) on the
same workload, assert identical exact K, and record the measured
speedup; the extended sizes (2e7, 1e8) run the pass-1 pair only (the
dense emit has nothing new to say there and the csr decode is
size-independent).

Rows:
  large_n/emit_{route}_n{N} — one ``plan.pairs`` call (us), route pinned
  large_n/emit_csr_decode_n{N} — one 8192-slot ``CSRPairs.decode`` (us)
  large_n/emit_pass1_{flat,hsbm}_n{N} — pass 1 alone (us), hybrid row
      carries ``ncells`` and ``speedup_vs_flat``
  derived: exact K, the route the policy would pick, truncation flag

``run_smoke()`` is the CI subset: one size per side of the resident
threshold (n+m = 1e5 and 6e5) plus 2.2e6 — past the streaming route's
~2.06e6 byte-budget bound, so CI proves the csr route, not a fallback,
is what runs in the regime the dense tables cannot reach — plus one
gated flat-vs-hybrid pass-1 pair at 6e5.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import MatchSpec, build_plan, grid, paper_workload
from repro.kernels import ops

from .common import bench, row

ALPHA = 0.5
CAP = 8192          # fixed capacity: bounds the interpret-mode grid
BLOCK = MatchSpec().block   # the block the benchmarked plans compile with
FULL_SIZES = (100_000, 500_000, 1_000_000, 2_000_000, 5_000_000,
              10_000_000)
# pass-1-only extension: the hybrid-vs-flat sort story past the dense
# emit's regime (the csr decode is size-independent, pass 1 is not)
PASS1_EXTRA_SIZES = (20_000_000, 100_000_000)
SMOKE_SIZES = (100_000, 600_000, 2_200_000)
PASS1_SMOKE_SIZE = 600_000


def _routes_for(n: int, m: int) -> list[str]:
    need = ops.emit_route_bytes(n, m, block=BLOCK)
    budget = ops._EMIT_VMEM_TABLE_BUDGET
    routes = [r for r in ("resident", "streaming", "csr")
              if need[r] <= budget]
    return routes + ["xla"]


def _sweep(sizes, iters: int = 2) -> None:
    for n_total in sizes:
        S, U = paper_workload(seed=41, n_total=n_total, alpha=ALPHA)
        auto = ops.choose_emit_route(S.n, U.n, block=BLOCK)
        want_pairs = want_k = None
        for route in _routes_for(S.n, U.n):
            spec = MatchSpec(algo="sbm", backend="pallas",
                             capacity="fixed", max_pairs=CAP,
                             emit_route=route, interpret=True)
            plan = build_plan(spec, S.n, U.n, S.d)
            pairs, k = plan.pairs(S, U)
            if route != "xla":
                assert ops.last_emit_route() == route, (route, n_total)
            dense = np.asarray(pairs)   # csr: assembles via decode windows
            if want_pairs is None:
                want_pairs, want_k = dense, k
            else:
                assert k == want_k, (route, n_total, k, want_k)
                np.testing.assert_array_equal(dense, want_pairs)
            t = bench(plan.pairs, S, U, iters=iters)
            row(f"large_n/emit_{route}_n{n_total}", t,
                f"K={k};auto_route={auto};truncated={int(k > CAP)}")
            if route == "csr":
                t = bench(lambda p=pairs: np.asarray(p.decode(0, CAP)),
                          iters=iters)
                row(f"large_n/emit_csr_decode_n{n_total}", t,
                    f"slots={CAP};nbytes={pairs.nbytes}")


def _pass1_rows(n_total: int, iters: int = 2) -> None:
    """Flat global-sort pass 1 vs the hybrid grid-bucketed pass 1."""
    S, U = paper_workload(seed=41, n_total=n_total, alpha=ALPHA)
    s_lo, s_hi = S.lo[:, 0], S.hi[:, 0]
    u_lo, u_hi = U.lo[:, 0], U.hi[:, 0]
    g = grid.hsbm_geometry(np.asarray(s_lo), np.asarray(s_hi),
                           np.asarray(u_lo), np.asarray(u_hi))
    lb, width = np.float32(g.lb), np.float32(g.width)

    def flat():
        return jax.block_until_ready(ops._twopass_tables(
            s_lo, s_hi, u_lo, u_hi, max_pairs=CAP))

    def hybrid():
        return jax.block_until_ready(ops._hsbm_tables(
            s_lo, s_hi, u_lo, u_hi, lb, width, max_pairs=CAP,
            **g.statics()))

    k_flat = int(np.sum(np.asarray(flat()[3]), dtype=np.int64))
    k_hsbm = int(np.sum(np.asarray(hybrid()[3]), dtype=np.int64))
    assert k_flat == k_hsbm, (n_total, k_flat, k_hsbm)
    tf = bench(flat, iters=iters)
    th = bench(hybrid, iters=iters)
    row(f"large_n/emit_pass1_flat_n{n_total}", tf, f"K={k_flat}")
    row(f"large_n/emit_pass1_hsbm_n{n_total}", th,
        f"K={k_hsbm};ncells={g.ncells};speedup_vs_flat={tf / th:.2f}")


def run() -> None:
    _sweep(FULL_SIZES)
    for n_total in FULL_SIZES + PASS1_EXTRA_SIZES:
        _pass1_rows(n_total, iters=2 if n_total <= 10_000_000 else 1)


def run_smoke() -> None:
    """CI smoke: resident/streaming thresholds plus the csr regime,
    and one gated flat-vs-hybrid pass-1 pair."""
    _sweep(SMOKE_SIZES, iters=2)
    _pass1_rows(PASS1_SMOKE_SIZE, iters=2)


if __name__ == "__main__":
    from .common import emit_header

    emit_header()
    run()
