"""Benchmark utilities: wall-clock timing + CSV rows.

Methodology note (EXPERIMENTS.md §Deviation): this container exposes ONE
physical CPU core, so the paper's speedup-vs-threads axis is reproduced
structurally (work decomposition + bit-equality under shard counts),
while WCT comparisons across algorithms / N / α reproduce directly.
"""
from __future__ import annotations

import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

ROWS: list[tuple[str, float, str]] = []


def plan_for(S, U, algo: str, **spec_kw):
    """Engine plan for a benchmark workload (plan-once-call-many)."""
    from repro.core import MatchSpec, build_plan

    return build_plan(MatchSpec(algo=algo, **spec_kw), S.n, U.n, S.d)


def bench(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Best-of-iters wall time in seconds (incl. building ancillary data
    structures, as the paper's WCT does; excludes input generation)."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.block_until_ready(r) if hasattr(r, "block_until_ready") else r
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        if hasattr(r, "block_until_ready"):
            r.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def row(name: str, seconds: float, derived: str = ""):
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def emit_header():
    print("name,us_per_call,derived", flush=True)


def bench_record() -> dict:
    """The accumulated ROWS as a BENCH_*.json-shaped trajectory record."""
    return {
        "meta": {
            "jax": jax.__version__,
            "devices": len(jax.devices()),
            "platform": platform.platform(),
        },
        "rows": {name: {"us": us, "derived": derived}
                 for name, us, derived in ROWS},
    }


def write_bench(path: str) -> dict:
    """Dump the accumulated ROWS as a BENCH_*.json trajectory file."""
    rec = bench_record()
    Path(path).write_text(json.dumps(rec, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {path} ({len(rec['rows'])} rows)", flush=True)
    return rec


def check_regression(bench: dict, baseline_path: str, factor: float = 2.0,
                     slack_us: float = 500.0
                     ) -> tuple[list[str], list[str]]:
    """Rows slower than ``factor``× baseline (+``slack_us`` absolute slack
    to keep sub-millisecond rows from tripping on scheduler noise).
    Baseline rows carrying ``"gate": false`` are trajectory-only (e.g.
    compile-time-bound rows, which vary too much across runner hardware
    to gate on absolute values).  Returns ``(fails, ratios)``: human-
    readable failure lines (empty means the gate is green) plus one
    new/old ratio line per gated row, for the full picture on failure.

    Every mismatch between the two row sets fails *by name*: a baseline
    row the run no longer produces, a run row the baseline has never
    seen (a new benchmark landed without refreshing the baseline — fix
    with ``--update-baseline``), and a baseline row without a ``us``
    value (hand-edited JSON) all get a clear message instead of a
    ``KeyError`` deep in the gate.
    """
    base = json.loads(Path(baseline_path).read_text())
    fails, ratios = [], []
    base_rows = base.get("rows", {})
    for name, ref in sorted(base_rows.items()):
        if not ref.get("gate", True):
            continue
        if "us" not in ref:
            fails.append(
                f"malformed baseline row {name!r}: no 'us' value in "
                f"{baseline_path} — refresh it with --update-baseline")
            continue
        cur = bench["rows"].get(name)
        if cur is None:
            fails.append(f"missing row vs baseline: {name}")
            ratios.append(f"{name}: missing (baseline {ref['us']:.1f}us)")
            continue
        limit = factor * ref["us"] + slack_us
        ratios.append(f"{name}: {cur['us'] / max(ref['us'], 1e-9):.2f}x "
                      f"({cur['us']:.1f}us vs {ref['us']:.1f}us)")
        if cur["us"] > limit:
            fails.append(
                f"{name}: {cur['us']:.1f}us > {factor:g}x baseline "
                f"{ref['us']:.1f}us (+{slack_us:g}us slack)")
    for name in sorted(set(bench["rows"]) - set(base_rows)):
        fails.append(
            f"row {name!r} is not in the baseline {baseline_path} — "
            "a new benchmark landed without refreshing it; run with "
            "--update-baseline to add it")
    return fails, ratios


def update_baseline(bench: dict, baseline_path: str,
                    headroom: float = 1.5) -> None:
    """Rewrite the committed baseline in place from this run's rows.

    Row values get ``headroom``× slack (the committed-baseline
    methodology — see the baseline's ``meta.note``); ``gate: false``
    markers and the note survive from the existing file, so a deliberate
    slowdown is a one-command refresh instead of hand-editing JSON.
    """
    path = Path(baseline_path)
    old = json.loads(path.read_text()) if path.exists() else {}
    old_rows = old.get("rows", {})
    rows = {}
    for name, cur in bench["rows"].items():
        entry = {"us": round(cur["us"] * headroom, 1),
                 "derived": cur["derived"]}
        if not old_rows.get(name, {}).get("gate", True):
            entry["gate"] = False
        rows[name] = entry
    rec = {"meta": {**bench["meta"],
                    **({"note": old["meta"]["note"]}
                       if "note" in old.get("meta", {}) else {})},
           "rows": rows}
    path.write_text(json.dumps(rec, indent=2, sort_keys=True) + "\n")
    print(f"# rewrote baseline {path} ({len(rows)} rows, "
          f"{headroom:g}x headroom)", flush=True)
