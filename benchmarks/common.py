"""Benchmark utilities: wall-clock timing + CSV rows.

Methodology note (EXPERIMENTS.md §Deviation): this container exposes ONE
physical CPU core, so the paper's speedup-vs-threads axis is reproduced
structurally (work decomposition + bit-equality under shard counts),
while WCT comparisons across algorithms / N / α reproduce directly.
"""
from __future__ import annotations

import time

import jax
import numpy as np

ROWS: list[tuple[str, float, str]] = []


def plan_for(S, U, algo: str, **spec_kw):
    """Engine plan for a benchmark workload (plan-once-call-many)."""
    from repro.core import MatchSpec, build_plan

    return build_plan(MatchSpec(algo=algo, **spec_kw), S.n, U.n, S.d)


def bench(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Best-of-iters wall time in seconds (incl. building ancillary data
    structures, as the paper's WCT does; excludes input generation)."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.block_until_ready(r) if hasattr(r, "block_until_ready") else r
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        if hasattr(r, "block_until_ready"):
            r.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def row(name: str, seconds: float, derived: str = ""):
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def emit_header():
    print("name,us_per_call,derived", flush=True)
