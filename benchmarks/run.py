"""Benchmark runner — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only figX]``
prints ``name,us_per_call,derived`` CSV (fig13 rows carry bytes — see
the unit tag in `derived`).
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

from .common import emit_header

MODULES = [
    "benchmarks.fig9_speedup",
    "benchmarks.fig11_gbm_cells",
    "benchmarks.fig12_scaling",
    "benchmarks.fig13_memory",
    "benchmarks.fig14_koln",
    "benchmarks.ddm_dynamic",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter, e.g. fig12")
    args = ap.parse_args()
    emit_header()
    t0 = time.time()
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        mod = importlib.import_module(name)
        print(f"# {name}", flush=True)
        mod.run()
    print(f"# total_wall_s,{time.time() - t0:.1f},", flush=True)


if __name__ == '__main__':
    main()
