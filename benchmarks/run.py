"""Benchmark runner — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only figX] [--smoke]``
prints ``name,us_per_call,derived`` CSV (fig13 rows carry bytes — see
the unit tag in `derived`).

``--smoke`` is the CI mode: compile a MatchPlan and run one tiny sweep
per backend available on CPU (``xla``, interpret-mode ``pallas``, and
``distributed`` over the local devices), assert cross-backend parity,
time the plan-reuse pattern, and measure the fig12c dist_pairs
strong-scaling endpoints (P = 1 vs P = 8, in an 8-device subprocess)
— minutes, not hours, so it runs on every PR.  ``--out BENCH_smoke.json`` records the rows as a JSON
trajectory file (uploaded as a CI artifact) and ``--baseline
benchmarks/baseline_smoke.json`` turns the run into a regression gate:
the process exits non-zero if any row is more than 2× slower than the
committed baseline.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

from .common import (bench, bench_record, check_regression, emit_header,
                     row, update_baseline, write_bench)

MODULES = [
    "benchmarks.fig9_speedup",
    "benchmarks.fig11_gbm_cells",
    "benchmarks.fig12_scaling",
    "benchmarks.fig13_memory",
    "benchmarks.fig14_koln",
    "benchmarks.ddm_dynamic",
    "benchmarks.plan_reuse",
    "benchmarks.large_n_emit",
]

SMOKE_N = 2048
SMOKE_ALGOS = ("bfm", "sbm", "hsbm", "itm")


def smoke() -> None:
    """Plan compilation + one tiny sweep per backend, with parity checks."""
    from repro.core import MatchSpec, build_plan, paper_workload

    S, U = paper_workload(seed=5, n_total=SMOKE_N, alpha=5.0)
    want = None
    for backend in ("xla", "pallas", "distributed"):
        # distributed implements the parallel-SBM family only
        algos = SMOKE_ALGOS if backend != "distributed" else ("sbm",)
        for algo in algos:
            spec = MatchSpec(algo=algo, backend=backend, capacity="grow",
                             interpret=(backend == "pallas"))
            plan = build_plan(spec, S.n, U.n, S.d)
            k = plan.count(S, U)
            if want is None:
                want = k
            assert k == want, (algo, backend, k, want)
            pairs, kp = plan.pairs(S, U)
            assert kp == want, (algo, backend, kp, want)
            warm = plan.traces
            t = bench(plan.pairs, S, U, iters=2)
            assert plan.traces == warm, (algo, backend, "retraced")
            row(f"smoke/{algo}_{backend}_n{SMOKE_N}", t,
                f"K={k};retraces=0")

    from . import ddm_dynamic, fig12_scaling, large_n_emit, plan_reuse

    plan_reuse.run_smoke()
    large_n_emit.run_smoke()
    ddm_dynamic.run_smoke()
    fig12_scaling.run_smoke()
    print("# smoke_parity_ok", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter, e.g. fig12")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny per-backend sweep + parity checks")
    ap.add_argument("--out", default=None, metavar="BENCH_smoke.json",
                    help="write the timing rows as a JSON trajectory file")
    ap.add_argument("--baseline", default=None,
                    metavar="benchmarks/baseline_smoke.json",
                    help="fail (exit 1) if any row regresses >2x vs this")
    ap.add_argument("--update-baseline", nargs="?", default=None,
                    const="benchmarks/baseline_smoke.json",
                    metavar="benchmarks/baseline_smoke.json",
                    help="rewrite the committed baseline in place from "
                         "this run's rows (1.5x headroom; preserves "
                         "gate:false markers and the meta note)")
    args = ap.parse_args()
    emit_header()
    t0 = time.time()
    if args.smoke:
        smoke()
    else:
        for name in MODULES:
            if args.only and args.only not in name:
                continue
            mod = importlib.import_module(name)
            print(f"# {name}", flush=True)
            mod.run()
    print(f"# total_wall_s,{time.time() - t0:.1f},", flush=True)
    rec = write_bench(args.out) if args.out else None
    if args.update_baseline:
        update_baseline(rec or bench_record(), args.update_baseline)
    if args.baseline:
        fails, ratios = check_regression(rec or bench_record(),
                                         args.baseline)
        for line in fails:
            print(f"# REGRESSION {line}", flush=True)
        if fails:
            # the full per-row picture, so a deliberate slowdown is a
            # one-command `--update-baseline` refresh, not JSON surgery
            print("# per-row new/old ratios vs baseline:", flush=True)
            for line in ratios:
                print(f"# RATIO {line}", flush=True)
            sys.exit(1)
        print("# bench_regression_gate_ok", flush=True)


if __name__ == '__main__':
    main()
