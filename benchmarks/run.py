"""Benchmark runner — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only figX] [--smoke]``
prints ``name,us_per_call,derived`` CSV (fig13 rows carry bytes — see
the unit tag in `derived`).

``--smoke`` is the CI mode: compile a MatchPlan and run one tiny sweep
per backend available on CPU (``xla`` and interpret-mode ``pallas``),
assert cross-backend parity, and time the plan-reuse pattern — minutes,
not hours, so it runs on every PR.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

from .common import bench, emit_header, row

MODULES = [
    "benchmarks.fig9_speedup",
    "benchmarks.fig11_gbm_cells",
    "benchmarks.fig12_scaling",
    "benchmarks.fig13_memory",
    "benchmarks.fig14_koln",
    "benchmarks.ddm_dynamic",
    "benchmarks.plan_reuse",
]

SMOKE_N = 2048
SMOKE_ALGOS = ("bfm", "sbm", "itm")


def smoke() -> None:
    """Plan compilation + one tiny sweep per backend, with parity checks."""
    from repro.core import MatchSpec, build_plan, paper_workload

    S, U = paper_workload(seed=5, n_total=SMOKE_N, alpha=5.0)
    want = None
    for backend in ("xla", "pallas"):
        for algo in SMOKE_ALGOS:
            spec = MatchSpec(algo=algo, backend=backend, capacity="grow",
                             interpret=(backend == "pallas"))
            plan = build_plan(spec, S.n, U.n, S.d)
            k = plan.count(S, U)
            if want is None:
                want = k
            assert k == want, (algo, backend, k, want)
            pairs, kp = plan.pairs(S, U)
            assert kp == want, (algo, backend, kp, want)
            warm = plan.traces
            t = bench(plan.pairs, S, U, iters=2)
            assert plan.traces == warm, (algo, backend, "retraced")
            row(f"smoke/{algo}_{backend}_n{SMOKE_N}", t,
                f"K={k};retraces=0")

    from . import plan_reuse

    plan_reuse.run_smoke()
    print("# smoke_parity_ok", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter, e.g. fig12")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny per-backend sweep + parity checks")
    args = ap.parse_args()
    emit_header()
    t0 = time.time()
    if args.smoke:
        smoke()
    else:
        for name in MODULES:
            if args.only and args.only not in name:
                continue
            mod = importlib.import_module(name)
            print(f"# {name}", flush=True)
            mod.run()
    print(f"# total_wall_s,{time.time() - t0:.1f},", flush=True)


if __name__ == '__main__':
    main()
