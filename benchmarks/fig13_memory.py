"""Fig. 13 — memory footprint vs N.

The paper reports peak RSS; in a jitted JAX program the analogous
deterministic quantity is the live-buffer footprint of each algorithm's
data structures, which we account exactly from array shapes (regions +
endpoint streams + tree arrays + grid tables).  Expected reproduction:
linear growth in N; SBM carries the largest constant (endpoint stream +
sort), BFM the smallest (tiles only).

The accounting is driven by ``MatchSpec`` — the same config value the
engine compiles — so the tile/cell knobs here are the knobs a
``build_plan`` call would actually use (no hand-copied constants); each
accounted spec is passed through ``build_plan`` so an invalid
configuration fails loudly instead of being silently accounted.
"""
from __future__ import annotations

from repro.core import MatchSpec, build_plan, paper_workload
from repro.core.grid import _capacities, _cell_spans  # noqa: F401

from .common import row


def _bytes_regions(n):
    return 2 * n * 4  # lo+hi f32 per region (1-D)


def run():
    # the accounted configurations ARE engine specs (paper's knobs)
    spec_bfm = MatchSpec(algo="bfm", backend="pallas", interpret=True)
    spec_gbm = MatchSpec(algo="gbm")
    for n in (10_000, 100_000, 1_000_000):
        S, U = paper_workload(seed=3, n_total=n, alpha=100.0)
        # planning the accounted specs pins the spec↔footprint link
        build_plan(spec_bfm, S.n, U.n, S.d)
        build_plan(spec_gbm, S.n, U.n, S.d)
        base = _bytes_regions(n)
        # BFM: tile buffers only (ts×tu mask + counters, from the spec)
        bfm = base + spec_bfm.ts * spec_bfm.tu * 4
        # SBM: endpoint values + flags + sort perm + cumsums (2N each)
        sbm = base + 2 * n * (4 + 4 + 4 + 8 + 4 + 4)
        # ITM: 5 arrays of 2^ceil(lg n) nodes (padded implicit tree)
        m = 1 << max((n // 2).bit_length() + 1, 1)
        itm = base + 5 * m * 4
        # GBM (spec.ncells cells): incidence + two member tables
        ncells = spec_gbm.ncells
        width = 1e6 / ncells
        span_s, cap_s = _capacities(S.lo[:, 0], S.hi[:, 0], 0.0, width,
                                    ncells)
        gbm = base + ncells * cap_s * 4 * 2 + 2 * n * span_s * 8
        row(f"fig13/bfm_bytes_n{n}", bfm / 1e6, "unit=bytes")
        row(f"fig13/sbm_bytes_n{n}", sbm / 1e6, "unit=bytes")
        row(f"fig13/itm_bytes_n{n}", itm / 1e6, "unit=bytes")
        row(f"fig13/gbm_bytes_n{n}", gbm / 1e6,
            f"unit=bytes;cap={cap_s};span={span_s}")
