"""Fig. 14 — the Cologne vehicular trace workload (clustered regions).

The public koln.tr trace is not downloadable offline; the generator in
``core.regions.koln_like_workload`` reproduces its 1-D projection
statistics (dense road-cluster mixture, ~1e6 regions of width 100 m on a
~20 km extent).  Paper claims reproduced: SBM fastest by a wide margin,
GBM slowest of the three (grid skew), all counts identical.
"""
from __future__ import annotations

from repro.core import koln_like_workload, match_count

from .common import bench, row

N_POS = 60_000   # cluster-skewed regime; the paper's 541,222 positions
                  # scale down ~9x for the single-core budget (the claim
                  # under test is ordinal: SBM fastest, GBM skew-hurt)


def run():
    S, U = koln_like_workload(seed=9, n_positions=N_POS)
    counts = {}
    t = bench(match_count, S, U, algo="gbm", ncells=3000, iters=2)
    counts["gbm"] = match_count(S, U, algo="gbm", ncells=3000)
    row("fig14/gbm_wct_3000cells", t, f"K={counts['gbm']}")

    t = bench(match_count, S, U, algo="itm", iters=2)
    counts["itm"] = match_count(S, U, algo="itm")
    row("fig14/itm_wct", t, f"K={counts['itm']}")

    t = bench(match_count, S, U, algo="sbm", iters=2)
    counts["sbm"] = match_count(S, U, algo="sbm")
    row("fig14/sbm_wct", t, f"K={counts['sbm']}")

    assert len(set(counts.values())) == 1, counts
