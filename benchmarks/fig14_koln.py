"""Fig. 14 — the Cologne vehicular trace workload (clustered regions).

The public koln.tr trace is not downloadable offline; the generator in
``core.regions.koln_like_workload`` reproduces its 1-D projection
statistics (dense road-cluster mixture, ~1e6 regions of width 100 m on a
~20 km extent).  Paper claims reproduced: SBM fastest by a wide margin,
GBM slowest of the three (grid skew), all counts identical.
"""
from __future__ import annotations

from repro.core import koln_like_workload

from .common import bench, plan_for, row

N_POS = 60_000   # cluster-skewed regime; the paper's 541,222 positions
                  # scale down ~9x for the single-core budget (the claim
                  # under test is ordinal: SBM fastest, GBM skew-hurt)


def run():
    S, U = koln_like_workload(seed=9, n_positions=N_POS)
    counts = {}
    for algo, name, kw in (("gbm", "fig14/gbm_wct_3000cells",
                            dict(ncells=3000)),
                           ("itm", "fig14/itm_wct", {}),
                           ("sbm", "fig14/sbm_wct", {})):
        plan = plan_for(S, U, algo, **kw)
        t = bench(plan.count, S, U, iters=2)
        counts[algo] = plan.count(S, U)
        row(name, t, f"K={counts[algo]}")

    assert len(set(counts.values())) == 1, counts
