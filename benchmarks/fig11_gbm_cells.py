"""Fig. 11 — GBM WCT sensitivity to the ncells tuning knob.

Paper claim: the optimum cell count is workload-dependent and drifts
erratically; correctness must not depend on it (our first-overlapped-cell
dedup replaces the res-set).  N scaled to CPU budget.
"""
from __future__ import annotations

from repro.core import paper_workload
from repro.core.grid import gbm_count

from .common import bench, plan_for, row

N = 100_000
ALPHA = 100.0


def run():
    S, U = paper_workload(seed=7, n_total=N, alpha=ALPHA)
    want = plan_for(S, U, "sbm").count(S, U)
    best = (None, float("inf"))
    for ncells in (30, 100, 300, 1000, 3000, 10000):
        t = bench(gbm_count, S, U, ncells=ncells, iters=2)
        k = gbm_count(S, U, ncells=ncells)
        assert k == want, (ncells, k, want)
        if t < best[1]:
            best = (ncells, t)
        row(f"fig11/gbm_ncells{ncells}", t, f"K={k}")
    row("fig11/gbm_best", best[1], f"ncells={best[0]}")
