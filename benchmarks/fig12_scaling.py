"""Fig. 12(a) — WCT vs N for ITM/SBM at α=100 (polylog growth);
Fig. 12(b) — WCT vs α at fixed N: SBM is α-independent, ITM is
output-sensitive (grows with α).  Paper ranges 1e7–1e8 scale to
1e4–1e6 on this host; the claims are about *shape*, which reproduces.
Section (c) sweeps the distributed backend over mesh sizes (powers of
two up to the local device count — run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise a
real multi-device mesh on CPU): count, the sharded two-pass pair emit,
and the sharded batched query, each parity-checked against ``xla``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import paper_workload

from .common import bench, plan_for, row


def _mesh_sizes():
    ndev = len(jax.devices())
    p, out = 1, []
    while p <= ndev:
        out.append(p)
        p *= 2
    return out


def run():
    # (a) WCT vs N at alpha = 100
    for n in (10_000, 100_000, 300_000, 1_000_000):
        S, U = paper_workload(seed=1, n_total=n, alpha=100.0)
        p_itm = plan_for(S, U, "itm")
        p_sbm = plan_for(S, U, "sbm")
        p_bin = plan_for(S, U, "sbm_binary")
        t_itm = bench(p_itm.count, S, U, iters=2)
        t_sbm = bench(p_sbm.count, S, U, iters=2)
        t_bin = bench(p_bin.count, S, U, iters=2)
        k = p_sbm.count(S, U)
        assert k == p_itm.count(S, U)
        row(f"fig12a/itm_n{n}", t_itm, f"K={k}")
        row(f"fig12a/sbm_n{n}", t_sbm, f"K={k}")
        row(f"fig12a/sbm_binary_n{n}", t_bin, f"K={k}")

    # (b) WCT vs alpha at N = 1e6
    n = 1_000_000
    for alpha in (0.01, 1.0, 100.0):
        S, U = paper_workload(seed=2, n_total=n, alpha=alpha)
        p_itm = plan_for(S, U, "itm")
        p_sbm = plan_for(S, U, "sbm")
        t_itm = bench(p_itm.count, S, U, iters=2)
        t_sbm = bench(p_sbm.count, S, U, iters=2)
        k = p_sbm.count(S, U)
        assert k == p_itm.count(S, U)
        row(f"fig12b/itm_alpha{alpha}", t_itm, f"K={k}")
        row(f"fig12b/sbm_alpha{alpha}", t_sbm, f"K={k}")

    # (c) distributed backend vs mesh size: count + sharded pair emit +
    # sharded batched query, parity-checked against the local engine
    from repro.core import itm

    n = 100_000
    S, U = paper_workload(seed=4, n_total=n, alpha=1.0)
    ref = plan_for(S, U, "sbm", capacity="exact")
    k_ref = ref.count(S, U)
    tree = itm.build_tree(U)
    q_lo, q_hi = S.lo[:4096], S.hi[:4096]
    devs = jax.devices()
    for p in _mesh_sizes():
        mesh = Mesh(np.array(devs[:p]), ("shards",))
        plan = plan_for(S, U, "sbm", backend="distributed", mesh=mesh,
                        capacity="exact")
        assert plan.count(S, U) == k_ref, p
        t_cnt = bench(plan.count, S, U, iters=2)
        t_pairs = bench(plan.pairs, S, U, iters=2)
        row(f"fig12c/dist_count_p{p}", t_cnt, f"K={k_ref}")
        row(f"fig12c/dist_pairs_p{p}", t_pairs, f"K={k_ref}")
        qplan = plan_for(S, U, "itm", backend="distributed", mesh=mesh,
                         capacity="grow", max_pairs=16)
        t_q = bench(qplan.query, tree, U, q_lo, q_hi, iters=2)
        row(f"fig12c/dist_query_p{p}", t_q, f"b={q_lo.shape[0]}")


# -- §c smoke: the dist_pairs endpoints (P = 1 vs P = 8) as CI rows ---------

_SMOKE_MARK = "FIG12C_SMOKE="


def _smoke_c(n: int = 100_000) -> list[tuple[str, float, str]]:
    """Time the distributed pair emit at the P = 1 and P = 8 endpoints.

    Needs >= 8 devices (``run_smoke`` forces them in a subprocess when
    the parent mesh is smaller).  Parity-checks the emitted K against
    the local engine before timing, so a wrong-but-fast emit can never
    post a row.
    """
    S, U = paper_workload(seed=4, n_total=n, alpha=1.0)
    k_ref = plan_for(S, U, "sbm", capacity="exact").count(S, U)
    devs = jax.devices()
    out = []
    for p in (1, 8):
        mesh = Mesh(np.array(devs[:p]), ("shards",))
        plan = plan_for(S, U, "sbm", backend="distributed", mesh=mesh,
                        capacity="exact")
        _, kp = plan.pairs(S, U)
        assert kp == k_ref, (p, kp, k_ref)
        t = bench(plan.pairs, S, U, iters=2)
        out.append((f"fig12c/dist_pairs_p{p}", t, f"K={k_ref}"))
    return out


def run_smoke() -> None:
    """CI rows for the §c strong-scaling endpoints.

    The smoke runner executes on however many devices the host exposes
    (one, on the CI runners), so the 8-shard measurement runs in a
    subprocess with ``--xla_force_host_platform_device_count=8`` and
    ships its rows back over stdout as a marked JSON line; they are
    re-emitted here so the regression gate sees them like any other row.
    """
    if len(jax.devices()) >= 8:
        for name, t, derived in _smoke_c():
            row(name, t, derived)
        return
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig12_scaling", "--smoke-c"],
        capture_output=True, text=True, env=env, timeout=1800)
    payload = [ln for ln in proc.stdout.splitlines()
               if ln.startswith(_SMOKE_MARK)]
    if proc.returncode != 0 or not payload:
        raise RuntimeError(
            "fig12c smoke subprocess failed "
            f"(rc={proc.returncode}):\n{proc.stderr[-2000:]}")
    for name, t, derived in json.loads(payload[-1][len(_SMOKE_MARK):]):
        row(name, t, derived)


if __name__ == "__main__":
    if "--smoke-c" in sys.argv:
        print(_SMOKE_MARK + json.dumps(_smoke_c()), flush=True)
    else:
        run()
