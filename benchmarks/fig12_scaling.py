"""Fig. 12(a) — WCT vs N for ITM/SBM at α=100 (polylog growth);
Fig. 12(b) — WCT vs α at fixed N: SBM is α-independent, ITM is
output-sensitive (grows with α).  Paper ranges 1e7–1e8 scale to
1e4–1e6 on this host; the claims are about *shape*, which reproduces.
"""
from __future__ import annotations

from repro.core import paper_workload, match_count

from .common import bench, row


def run():
    # (a) WCT vs N at alpha = 100
    for n in (10_000, 100_000, 300_000, 1_000_000):
        S, U = paper_workload(seed=1, n_total=n, alpha=100.0)
        t_itm = bench(match_count, S, U, algo="itm", iters=2)
        t_sbm = bench(match_count, S, U, algo="sbm", iters=2)
        t_bin = bench(match_count, S, U, algo="sbm_binary", iters=2)
        k = match_count(S, U, algo="sbm")
        assert k == match_count(S, U, algo="itm")
        row(f"fig12a/itm_n{n}", t_itm, f"K={k}")
        row(f"fig12a/sbm_n{n}", t_sbm, f"K={k}")
        row(f"fig12a/sbm_binary_n{n}", t_bin, f"K={k}")

    # (b) WCT vs alpha at N = 1e6
    n = 1_000_000
    for alpha in (0.01, 1.0, 100.0):
        S, U = paper_workload(seed=2, n_total=n, alpha=alpha)
        t_itm = bench(match_count, S, U, algo="itm", iters=2)
        t_sbm = bench(match_count, S, U, algo="sbm", iters=2)
        k = match_count(S, U, algo="sbm")
        assert k == match_count(S, U, algo="itm")
        row(f"fig12b/itm_alpha{alpha}", t_itm, f"K={k}")
        row(f"fig12b/sbm_alpha{alpha}", t_sbm, f"K={k}")
