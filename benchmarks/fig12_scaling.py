"""Fig. 12(a) — WCT vs N for ITM/SBM at α=100 (polylog growth);
Fig. 12(b) — WCT vs α at fixed N: SBM is α-independent, ITM is
output-sensitive (grows with α).  Paper ranges 1e7–1e8 scale to
1e4–1e6 on this host; the claims are about *shape*, which reproduces.
"""
from __future__ import annotations

from repro.core import paper_workload

from .common import bench, plan_for, row


def run():
    # (a) WCT vs N at alpha = 100
    for n in (10_000, 100_000, 300_000, 1_000_000):
        S, U = paper_workload(seed=1, n_total=n, alpha=100.0)
        p_itm = plan_for(S, U, "itm")
        p_sbm = plan_for(S, U, "sbm")
        p_bin = plan_for(S, U, "sbm_binary")
        t_itm = bench(p_itm.count, S, U, iters=2)
        t_sbm = bench(p_sbm.count, S, U, iters=2)
        t_bin = bench(p_bin.count, S, U, iters=2)
        k = p_sbm.count(S, U)
        assert k == p_itm.count(S, U)
        row(f"fig12a/itm_n{n}", t_itm, f"K={k}")
        row(f"fig12a/sbm_n{n}", t_sbm, f"K={k}")
        row(f"fig12a/sbm_binary_n{n}", t_bin, f"K={k}")

    # (b) WCT vs alpha at N = 1e6
    n = 1_000_000
    for alpha in (0.01, 1.0, 100.0):
        S, U = paper_workload(seed=2, n_total=n, alpha=alpha)
        p_itm = plan_for(S, U, "itm")
        p_sbm = plan_for(S, U, "sbm")
        t_itm = bench(p_itm.count, S, U, iters=2)
        t_sbm = bench(p_sbm.count, S, U, iters=2)
        k = p_sbm.count(S, U)
        assert k == p_itm.count(S, U)
        row(f"fig12b/itm_alpha{alpha}", t_itm, f"K={k}")
        row(f"fig12b/sbm_alpha{alpha}", t_sbm, f"K={k}")
