"""Splice the live roofline table + dry-run summary + benchmark
trajectory into EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.assemble_experiments

Sections are anchored by HTML-comment markers; the benchmark trajectory
is built from any ``BENCH_*.json`` files in the repo root (the records
``benchmarks.run --out`` writes and the CI bench-smoke job uploads), via
``roofline.bench_table`` — so the committed experiment log and the CI
artifact share one formatter.  A missing EXPERIMENTS.md is created from
a stub so the tool works on a fresh checkout.
"""
from __future__ import annotations

import io
import json
import sys
from contextlib import redirect_stdout
from pathlib import Path

from . import roofline

MARK = "<!-- ROOFLINE_TABLE -->"
BENCH_MARK = "<!-- BENCH_TRAJECTORY -->"
STUB = ("# EXPERIMENTS\n\n" + MARK + "\n\n" + BENCH_MARK + "\n")


def table(mesh: str) -> str:
    buf = io.StringIO()
    argv = sys.argv
    sys.argv = ["roofline", "--md", "--mesh", mesh]
    try:
        with redirect_stdout(buf):
            roofline.main()
    finally:
        sys.argv = argv
    return buf.getvalue()


def summary() -> str:
    recs = [json.loads(p.read_text())
            for p in Path("experiments/dryrun").glob("*.json")]
    if not recs:
        return "**Status: no dry-run records (experiments/dryrun empty).**\n"
    ok = sum(1 for r in recs if r.get("status") == "ok")
    skip = sum(1 for r in recs if r.get("status") == "skipped")
    err = sum(1 for r in recs if r.get("status") == "error")
    fits = sum(1 for r in recs if r.get("status") == "ok"
               and r["memory"].get("peak_tpu_estimate",
                                   r["memory"]["peak_estimate"]) < 16e9)
    worst = max((r["memory"].get("peak_tpu_estimate", 0), r["arch"],
                 r["shape"], r["mesh"])
                for r in recs if r.get("status") == "ok")
    return (f"**Status: {ok} compiled ok / {skip} documented skips / "
            f"{err} errors; {fits}/{ok} within the 16 GB v5e budget "
            f"(TPU-corrected); worst cell {worst[1]} {worst[2]} "
            f"{worst[3]} at {worst[0] / 1e9:.1f} GB.**\n")


def bench_section() -> str:
    paths = sorted(Path(".").glob("BENCH_*.json"))
    if not paths:
        return (BENCH_MARK + "\n\n(no BENCH_*.json records yet — run "
                "`python -m benchmarks.run --smoke --out BENCH_smoke.json`)\n")
    return (BENCH_MARK + "\n\n### Benchmark trajectory\n\n"
            + roofline.bench_table(paths))


def main():
    path = Path("EXPERIMENTS.md")
    md = path.read_text() if path.exists() else STUB
    if MARK not in md:
        md = md.rstrip() + "\n\n" + MARK + "\n"
    block = (MARK + "\n\n" + summary() + "\n### Single-pod (16×16)\n\n"
             + table("single") + "\n### Multi-pod (2×16×16)\n\n"
             + table("multi") + "\n" + bench_section())
    pre = md.split(MARK)[0]
    post = md.split(MARK)[-1]
    # keep everything after the old marker section's next heading
    tail_idx = post.find("\n## §Perf")
    tail = post[tail_idx:] if tail_idx >= 0 else ""
    path.write_text(pre + block + tail)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
