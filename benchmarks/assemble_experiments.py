"""Splice the live roofline table + dry-run summary into EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.assemble_experiments
"""
from __future__ import annotations

import io
import json
import sys
from contextlib import redirect_stdout
from pathlib import Path

from . import roofline

MARK = "<!-- ROOFLINE_TABLE -->"


def table(mesh: str) -> str:
    buf = io.StringIO()
    argv = sys.argv
    sys.argv = ["roofline", "--md", "--mesh", mesh]
    try:
        with redirect_stdout(buf):
            roofline.main()
    finally:
        sys.argv = argv
    return buf.getvalue()


def summary() -> str:
    recs = [json.loads(p.read_text())
            for p in Path("experiments/dryrun").glob("*.json")]
    ok = sum(1 for r in recs if r.get("status") == "ok")
    skip = sum(1 for r in recs if r.get("status") == "skipped")
    err = sum(1 for r in recs if r.get("status") == "error")
    fits = sum(1 for r in recs if r.get("status") == "ok"
               and r["memory"].get("peak_tpu_estimate",
                                   r["memory"]["peak_estimate"]) < 16e9)
    worst = max((r["memory"].get("peak_tpu_estimate", 0), r["arch"],
                 r["shape"], r["mesh"])
                for r in recs if r.get("status") == "ok")
    return (f"**Status: {ok} compiled ok / {skip} documented skips / "
            f"{err} errors; {fits}/{ok} within the 16 GB v5e budget "
            f"(TPU-corrected); worst cell {worst[1]} {worst[2]} "
            f"{worst[3]} at {worst[0] / 1e9:.1f} GB.**\n")


def main():
    md = Path("EXPERIMENTS.md").read_text()
    block = (MARK + "\n\n" + summary() + "\n### Single-pod (16×16)\n\n"
             + table("single") + "\n### Multi-pod (2×16×16)\n\n"
             + table("multi"))
    pre = md.split(MARK)[0]
    post = md.split(MARK)[-1]
    # keep everything after the old marker section's next heading
    tail_idx = post.find("\n## §Perf")
    tail = post[tail_idx:] if tail_idx >= 0 else ""
    Path("EXPERIMENTS.md").write_text(pre + block + tail)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
