"""Plan-reuse sweep: plan-once-call-many vs recompile-per-call.

Quantifies what the MatchSpec → MatchPlan split buys: a reused plan
amortizes tracing/compilation across calls (steady state is pure
execution — ``plan.traces`` stays flat), while rebuilding a fresh
``MatchPlan`` per call pays the trace every time (the pre-engine
behavior whenever a caller re-derived capacities per call).

Rows:
  plan_reuse/{algo}_reused_n{N}    — one plan, many calls (us/call)
  plan_reuse/{algo}_recompile_n{N} — fresh plan every call (us/call)
  derived: exact K, retraces observed per call pattern
"""
from __future__ import annotations

from repro.core import MatchSpec, paper_workload
from repro.core.engine import MatchPlan

from .common import bench, row

ALGOS = ("sbm", "itm", "bfm")


def _sweep(n_total: int, alpha: float, iters: int = 3):
    S, U = paper_workload(seed=23, n_total=n_total, alpha=alpha)
    for algo in ALGOS:
        spec = MatchSpec(algo=algo, capacity="grow")
        plan = MatchPlan(spec, S.n, U.n, S.d)
        pairs, k = plan.pairs(S, U)            # warm the plan
        warm = plan.traces

        t_reuse = bench(plan.pairs, S, U, iters=iters)
        reuse_traces = plan.traces - warm
        row(f"plan_reuse/{algo}_reused_n{n_total}", t_reuse,
            f"K={k};retraces_per_call={reuse_traces}")

        def fresh_call():
            p = MatchPlan(spec, S.n, U.n, S.d)  # no build_plan cache
            return p.pairs(S, U)

        t_fresh = bench(fresh_call, warmup=1, iters=iters)
        row(f"plan_reuse/{algo}_recompile_n{n_total}", t_fresh,
            f"K={k};speedup_from_reuse={t_fresh / max(t_reuse, 1e-9):.1f}x")


def run():
    _sweep(20_000, 10.0)
    _sweep(100_000, 10.0)


def run_smoke():
    """CI smoke: one tiny sweep, assertions over parity and retraces.

    Best-of-3 iterations: single-iteration timings are too noisy for
    the 2x benchmark-regression gate on shared CI runners.
    """
    _sweep(512, 2.0, iters=3)


if __name__ == "__main__":
    from .common import emit_header

    emit_header()
    run()
