"""Dynamic DDM engine scenario: batch size × region churn rate sweep.

Measures the batched ``DDMService.update_regions`` tick cost against the
equivalent sequence of single-region updates (the paper's §3 operation),
for d ∈ {1, 2}, plus the exact two-pass pair enumeration across the
overlap-degree sweep (the path that replaced the bounded-window emit).

Rows:
  dynamic_d{d}_churn{pct}_batched   — one batched call moving b regions
  dynamic_d{d}_churn{pct}_seq       — b single-region update calls
  dynamic_dist_d{d}_churn{pct}_p{P} — the same batched tick with the
                                      query sharded over a P-device mesh
                                      (backend="distributed")
  twopass_pairs_n{N}_a{alpha}       — exact enumeration, K pairs emitted
"""
from __future__ import annotations

import numpy as np

from repro.core import DDMService, MatchSpec, build_plan, paper_workload

from .common import bench, row

N_TOTAL = 4096
CHURN = (0.01, 0.1, 0.5)
DIMS = (1, 2)


def _fresh_service(d: int, spec: MatchSpec | None = None) -> DDMService:
    S, U = paper_workload(seed=7, n_total=N_TOTAL, alpha=5.0, d=d)
    svc = DDMService(S, U, spec=spec)
    svc.connect()
    return svc


def _moves(rng, svc: DDMService, b: int, d: int):
    n = svc.s_lo.shape[0]
    idx = rng.choice(n, size=b, replace=False)
    lo = rng.uniform(0, 9e5, (b, d)).astype(np.float32)
    hi = lo + rng.uniform(1.0, 5e3, (b, d)).astype(np.float32)
    return idx, lo, hi


def run():
    rng = np.random.default_rng(0)
    for d in DIMS:
        for churn in CHURN:
            svc = _fresh_service(d)
            b = max(int(churn * svc.s_lo.shape[0]), 1)
            idx, lo, hi = _moves(rng, svc, b, d)

            def batched():
                svc.update_regions("sub", idx, lo, hi)

            def sequential():
                for i in range(b):
                    svc.update_region("sub", int(idx[i]), lo[i], hi[i])

            t_b = bench(batched, iters=3)
            row(f"dynamic_d{d}_churn{int(churn * 100)}_batched", t_b,
                f"b={b}")
            t_s = bench(sequential, iters=1)
            row(f"dynamic_d{d}_churn{int(churn * 100)}_seq", t_s,
                f"b={b} speedup={t_s / t_b:.1f}x")

    # the same batched tick with the per-tick query sharded over the mesh
    import jax

    ndev = len(jax.devices())
    dist_spec = MatchSpec(algo="itm", backend="distributed",
                          capacity="grow")
    for d in DIMS:
        svc = _fresh_service(d, spec=dist_spec)
        b = max(int(0.1 * svc.s_lo.shape[0]), 1)
        idx, lo, hi = _moves(rng, svc, b, d)
        t_d = bench(lambda: svc.update_regions("sub", idx, lo, hi),
                    iters=3)
        row(f"dynamic_dist_d{d}_churn10_p{ndev}", t_d, f"b={b}")

    for n_total, alpha in ((4096, 1.0), (4096, 100.0), (16384, 10.0)):
        S, U = paper_workload(seed=11, n_total=n_total, alpha=alpha)
        plan = build_plan(MatchSpec(algo="sbm", capacity="exact"),
                          S.n, U.n, S.d)
        _, k = plan.pairs(S, U)
        t = bench(plan.pairs, S, U)
        row(f"twopass_pairs_n{n_total}_a{alpha:g}", t, f"K={k}")


if __name__ == "__main__":
    from .common import emit_header

    emit_header()
    run()
