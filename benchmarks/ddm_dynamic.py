"""Dynamic DDM engine scenario: batch size × region churn rate sweep.

Measures the batched ``DDMService.update_regions`` tick cost against the
equivalent sequence of single-region updates (the paper's §3 operation),
for d ∈ {1, 2}, plus the exact two-pass pair enumeration across the
overlap-degree sweep (the path that replaced the bounded-window emit).

Rows:
  dynamic_d{d}_churn{pct}_batched   — one batched call moving b regions
  dynamic_d{d}_churn{pct}_seq       — b single-region update calls
  dynamic_dist_d{d}_churn{pct}_p{P} — the same batched tick with the
                                      query sharded over a P-device mesh
                                      (backend="distributed")
  twopass_pairs_n{N}_a{alpha}       — exact enumeration, K pairs emitted

Serving-layer rows (``repro.serve`` driven through its churn harness):
  serve/churn_p99_query       — steady-state p99 query latency under
                                multi-tenant churn (smoke scale, gated)
  serve/churn_rebuild_p50     — double-buffered rebuild+publish median
                                (smoke scale, gated)
  serve/compile_cold|warm     — first-compile vs persistent-cache
                                warm-start (gate:false — compile-bound)
  serve/churn_n1e6_*          — full-scale trajectory: 1e6 regions,
                                1e4 moves/tick (full mode only)
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import DDMService, MatchSpec, build_plan, paper_workload

from .common import bench, row

N_TOTAL = 4096
CHURN = (0.01, 0.1, 0.5)
DIMS = (1, 2)


def _fresh_service(d: int, spec: MatchSpec | None = None) -> DDMService:
    S, U = paper_workload(seed=7, n_total=N_TOTAL, alpha=5.0, d=d)
    svc = DDMService(S, U, spec=spec)
    svc.connect()
    return svc


def _moves(rng, svc: DDMService, b: int, d: int):
    n = svc.s_lo.shape[0]
    idx = rng.choice(n, size=b, replace=False)
    lo = rng.uniform(0, 9e5, (b, d)).astype(np.float32)
    hi = lo + rng.uniform(1.0, 5e3, (b, d)).astype(np.float32)
    return idx, lo, hi


def _serve_rows(prefix: str, stats: dict, extra: str = "") -> None:
    """Emit the serving harness' steady-state stats as bench rows."""
    lag = 0.0
    for tm in stats["metrics"]["tenants"].values():
        lag = max(lag, tm["rebuild_lag_versions"]["max"])
    derived = (f"parity={stats['parity_checks']};max_lag={lag:g}"
               + (f";{extra}" if extra else ""))
    row(f"{prefix}_p99_query", stats["p99_query_s"], derived)
    row(f"{prefix}_p99_stale", stats["p99_stale_query_s"],
        "mid-churn answers only")
    row(f"{prefix}_rebuild_p50", stats["rebuild_p50_s"],
        "capture+build+publish")
    row(f"{prefix}_rebuild_p99", stats["rebuild_p99_s"], "")


def _compile_cache_rows() -> None:
    """First-compile vs warm-start through the persistent compilation
    cache: two fresh ``MatchPlan`` instances at shapes nothing else in
    this process compiles — the first XLA compile misses the disk cache
    and writes it, the second should be served from it.  Compile-bound,
    so both rows are trajectory-only (gate:false in the baseline)."""
    import tempfile

    from repro.core.engine import MatchPlan
    from repro.serve import compile_cache

    cache_dir = tempfile.mkdtemp(prefix="repro-jaxcache-")
    compile_cache.enable(cache_dir)
    S, U = paper_workload(seed=13, n_total=2994, alpha=5.0)
    spec = MatchSpec(algo="itm", capacity="fixed", max_pairs=64)

    def first_call_s() -> float:
        plan = MatchPlan(spec, S.n, U.n, S.d)
        t0 = time.perf_counter()
        plan.count(S, U)
        return time.perf_counter() - t0

    cold = first_call_s()
    warm = first_call_s()
    row("serve/compile_cold", cold, "persistent-cache miss (writes it)")
    row("serve/compile_warm", warm,
        f"cache hit;speedup={cold / max(warm, 1e-9):.1f}x")


def run_smoke() -> None:
    """Smoke-scale serving churn: the CI-gated p99/rebuild rows plus the
    (ungated) compile-cache comparison."""
    from repro.serve.harness import run_churn

    stats = run_churn(tenants=2, n_total=1024, ticks=4, warmup=2,
                      moves_per_tick=32, queries_per_tick=24,
                      max_batch=32, cap_hint=256, seed=1)
    assert stats["parity_checks"] > 0, "serving oracle never exercised"
    _serve_rows("serve/churn", stats,
                extra="tenants=2;n=1024;moves=32/tick")
    _compile_cache_rows()


def run_serving_full() -> None:
    """Full-scale churn trajectory — the ISSUE's 1e6-regions / 1e4-moves
    regime.  Never gated (full runs have no baseline); rows chart the
    large-N serving envelope over time."""
    from repro.serve.harness import run_churn

    stats = run_churn(tenants=1, n_total=1_000_000, ticks=3, warmup=1,
                      moves_per_tick=10_000, queries_per_tick=64,
                      max_batch=64, cap_hint=8192, seed=2,
                      d_cycle=(1,))
    _serve_rows("serve/churn_n1e6", stats,
                extra="n=1e6;moves=1e4/tick")


def run():
    rng = np.random.default_rng(0)
    for d in DIMS:
        for churn in CHURN:
            svc = _fresh_service(d)
            b = max(int(churn * svc.s_lo.shape[0]), 1)
            idx, lo, hi = _moves(rng, svc, b, d)

            def batched():
                svc.update_regions("sub", idx, lo, hi)

            def sequential():
                for i in range(b):
                    svc.update_region("sub", int(idx[i]), lo[i], hi[i])

            t_b = bench(batched, iters=3)
            row(f"dynamic_d{d}_churn{int(churn * 100)}_batched", t_b,
                f"b={b}")
            t_s = bench(sequential, iters=1)
            row(f"dynamic_d{d}_churn{int(churn * 100)}_seq", t_s,
                f"b={b} speedup={t_s / t_b:.1f}x")

    # the same batched tick with the per-tick query sharded over the mesh
    import jax

    ndev = len(jax.devices())
    dist_spec = MatchSpec(algo="itm", backend="distributed",
                          capacity="grow")
    for d in DIMS:
        svc = _fresh_service(d, spec=dist_spec)
        b = max(int(0.1 * svc.s_lo.shape[0]), 1)
        idx, lo, hi = _moves(rng, svc, b, d)
        t_d = bench(lambda: svc.update_regions("sub", idx, lo, hi),
                    iters=3)
        row(f"dynamic_dist_d{d}_churn10_p{ndev}", t_d, f"b={b}")

    for n_total, alpha in ((4096, 1.0), (4096, 100.0), (16384, 10.0)):
        S, U = paper_workload(seed=11, n_total=n_total, alpha=alpha)
        plan = build_plan(MatchSpec(algo="sbm", capacity="exact"),
                          S.n, U.n, S.d)
        _, k = plan.pairs(S, U)
        t = bench(plan.pairs, S, U)
        row(f"twopass_pairs_n{n_total}_a{alpha:g}", t, f"K={k}")

    run_serving_full()


if __name__ == "__main__":
    from .common import emit_header

    emit_header()
    run()
