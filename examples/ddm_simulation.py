"""HLA-style road-traffic pub/sub simulation (paper §1, Fig. 1).

Vehicles move along a 2-D ring road: dimension 0 is the position along
the road, dimension 1 the lane.  Each vehicle owns
  * an update region centred on its position (its "area of influence",
    confined to its own lane band),
  * a subscription region skewed toward its direction of motion and
    spanning its lane plus the neighbouring one ("a vehicle can safely
    ignore what happens behind it" — paper §1);
traffic lights own update regions only, spanning every lane.  Every tick
ALL vehicles move, and the DDM service recomputes the overlap deltas with
one batched ``update_regions`` call per region kind — two device
round-trips per tick instead of two per vehicle.

    PYTHONPATH=src python examples/ddm_simulation.py
"""
import numpy as np

from repro.core import DDMService, make_regions

ROAD = 10_000.0
N_LANES = 4
N_VEHICLES = 120
N_LIGHTS = 12
TICKS = 20


def _vehicle_regions(pos, lane):
    """(sub_lo, sub_hi, upd_lo, upd_hi), each (n, 2), for vehicle state."""
    sub_lo = np.stack([pos - 10.0, lane - 1.0], axis=1)
    sub_hi = np.stack([pos + 80.0, lane + 2.0], axis=1)
    upd_lo = np.stack([pos - 15.0, lane + 0.0], axis=1)
    upd_hi = np.stack([pos + 15.0, lane + 1.0], axis=1)
    return sub_lo, sub_hi, upd_lo, upd_hi


def main():
    rng = np.random.default_rng(0)
    pos = rng.uniform(0, ROAD, N_VEHICLES)
    lane = rng.integers(0, N_LANES, N_VEHICLES).astype(np.float64)
    speed = rng.uniform(5.0, 25.0, N_VEHICLES)

    sub_lo, sub_hi, upd_lo, upd_hi = _vehicle_regions(pos, lane)
    # traffic lights: fixed 60 m bands across all lanes
    light_x = np.linspace(0, ROAD, N_LIGHTS)
    light_lo = np.stack([light_x - 30.0, np.zeros(N_LIGHTS)], axis=1)
    light_hi = np.stack([light_x + 30.0,
                         np.full(N_LIGHTS, float(N_LANES))], axis=1)

    svc = DDMService(make_regions(sub_lo, sub_hi),
                     make_regions(np.concatenate([upd_lo, light_lo]),
                                  np.concatenate([upd_hi, light_hi])))
    pairs = svc.connect()
    print(f"tick  0: {len(pairs):4d} active (subscriber, publisher) "
          f"routes")

    vehicle_ids = np.arange(N_VEHICLES)
    total_events = len(pairs)
    for tick in range(1, TICKS + 1):
        pos = (pos + speed) % ROAD
        # occasional lane changes keep dimension 1 dynamic too
        switch = rng.random(N_VEHICLES) < 0.05
        lane = np.where(switch,
                        np.clip(lane + rng.choice([-1.0, 1.0],
                                                  N_VEHICLES), 0,
                                N_LANES - 1),
                        lane)
        sub_lo, sub_hi, upd_lo, upd_hi = _vehicle_regions(pos, lane)
        # one batched update per region kind — the whole tick's churn
        a1, r1 = svc.update_regions("sub", vehicle_ids, sub_lo, sub_hi)
        a2, r2 = svc.update_regions("upd", vehicle_ids, upd_lo, upd_hi)
        delta_add = len(a1) + len(a2)
        delta_rm = len(r1) + len(r2)
        total_events += delta_add
        print(f"tick {tick:2d}: {len(svc.pairs):4d} routes "
              f"(+{delta_add:3d}/-{delta_rm:3d} this tick)")

    # cross-check the incremental ledger against a from-scratch match
    from repro.core import MatchSpec, build_plan
    S = make_regions(svc.s_lo, svc.s_hi)
    U = make_regions(svc.u_lo, svc.u_hi)
    k = build_plan(MatchSpec(algo="sbm"), S.n, U.n, S.d).count(S, U)
    assert k == len(svc.pairs), (k, len(svc.pairs))
    print(f"\nledger == from-scratch SBM match ({k} routes); "
          f"{total_events} route-creation events delivered total")


if __name__ == "__main__":
    main()
