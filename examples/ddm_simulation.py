"""HLA-style road-traffic pub/sub simulation (paper §1, Fig. 1).

Vehicles move along a 1-D ring road.  Each vehicle owns
  * an update region centred on its position (its "area of influence"),
  * a subscription region skewed toward its direction of motion
    ("a vehicle can safely ignore what happens behind it" — paper §1);
traffic lights own update regions only.  Every tick the DDM service
recomputes the overlap deltas for moved vehicles; matched pairs are the
event routes the RTI would deliver.

    PYTHONPATH=src python examples/ddm_simulation.py
"""
import numpy as np

from repro.core import DDMService, make_regions

ROAD = 10_000.0
N_VEHICLES = 120
N_LIGHTS = 12
TICKS = 20


def main():
    rng = np.random.default_rng(0)
    pos = rng.uniform(0, ROAD, N_VEHICLES)
    speed = rng.uniform(5.0, 25.0, N_VEHICLES)

    # subscriptions: vehicles look ahead 80 m, back 10 m
    sub_lo = pos - 10.0
    sub_hi = pos + 80.0
    # updates: vehicles radiate 15 m around; lights 30 m, fixed
    upd_lo = np.concatenate([pos - 15.0,
                             np.linspace(0, ROAD, N_LIGHTS) - 30.0])
    upd_hi = np.concatenate([pos + 15.0,
                             np.linspace(0, ROAD, N_LIGHTS) + 30.0])

    svc = DDMService(make_regions(sub_lo[:, None], sub_hi[:, None]),
                     make_regions(upd_lo[:, None], upd_hi[:, None]))
    pairs = svc.connect()
    print(f"tick  0: {len(pairs):4d} active (subscriber, publisher) "
          f"routes")

    total_events = len(pairs)
    for tick in range(1, TICKS + 1):
        pos = (pos + speed) % ROAD
        n_changed, delta_add, delta_rm = 0, 0, 0
        for v in range(N_VEHICLES):
            # vehicle v's subscription and update regions both move
            a1, r1 = svc.update_region("sub", v, pos[v] - 10.0,
                                       pos[v] + 80.0)
            a2, r2 = svc.update_region("upd", v, pos[v] - 15.0,
                                       pos[v] + 15.0)
            delta_add += len(a1) + len(a2)
            delta_rm += len(r1) + len(r2)
            n_changed += 1
        total_events += delta_add
        print(f"tick {tick:2d}: {len(svc.pairs):4d} routes "
              f"(+{delta_add:3d}/-{delta_rm:3d} this tick)")

    # cross-check the incremental ledger against a from-scratch match
    from repro.core import match_count
    S = make_regions(svc.s_lo[:, None], svc.s_hi[:, None])
    U = make_regions(svc.u_lo[:, None], svc.u_hi[:, None])
    k = match_count(S, U, algo="sbm")
    assert k == len(svc.pairs), (k, len(svc.pairs))
    print(f"\nledger == from-scratch SBM match ({k} routes); "
          f"{total_events} route-creation events delivered total")


if __name__ == "__main__":
    main()
