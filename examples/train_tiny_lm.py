"""End-to-end driver: train a small LM with the full stack —
model library + optimizer + deterministic data pipeline + fault-tolerant
runtime with an injected failure + checkpoint restart.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 60]

Uses a ~1.5M-param llama-family config by default so it finishes on one
CPU core in a couple of minutes; pass --d-model/--layers to scale up (the
same driver trains any `repro.configs` arch via --arch).
"""
import argparse
import dataclasses
import time

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="use a repro.configs smoke arch instead")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/train_tiny_ckpt")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    if args.arch:
        cfg = get_smoke_config(args.arch)
    else:
        cfg = ModelConfig(
            name="tiny-llama", family="dense", n_layers=args.layers,
            d_model=args.d_model, n_heads=4, n_kv_heads=2,
            d_head=args.d_model // 4, d_ff=args.d_model * 3,
            vocab=2048, remat=False)
    print(f"training {cfg.name}: ~{cfg.n_params() / 1e6:.1f}M params")

    tr = Trainer(
        cfg,
        AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps),
        TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=20,
                      async_ckpt=True),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch))

    t0 = time.time()
    hist = []

    def log(step, m):
        hist.append(float(m["loss"]))
        if step % 10 == 0:
            print(f"step {step:4d} loss {hist[-1]:.4f} "
                  f"({args.batch * args.seq * (step + 1) / (time.time() - t0):,.0f} tok/s)",
                  flush=True)

    failures = (args.fail_at,) if args.fail_at is not None else ()
    tr.run_resilient(args.steps, failures=failures, on_step=log)
    print(f"\nloss {hist[0]:.3f} -> {hist[-1]:.3f} over {args.steps} "
          f"steps, wall {time.time() - t0:.1f}s"
          + (" (survived injected failure + restart)" if failures else ""))
    assert hist[-1] < hist[0], "loss must decrease"


if __name__ == "__main__":
    main()
