"""Quickstart: the DDM matching service in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (DDMService, MatchSpec, build_plan, make_regions,
                        paper_workload, pairs_to_set)

# --- 1. the region matching problem (paper Fig. 3) -------------------------
S = make_regions([[1.0, 1.0], [4.0, 0.5], [2.5, 2.0]],
                 [[3.0, 3.0], [6.0, 2.5], [5.0, 4.0]])   # 3 subscriptions
U = make_regions([[2.0, 2.0], [4.5, 1.0]],
                 [[4.0, 4.0], [5.5, 3.0]])               # 2 updates

print("== 2-D matching: one engine, interchangeable algorithms ==")
for algo in ("bfm", "sbm", "itm"):
    plan = build_plan(MatchSpec(algo=algo), S.n, U.n, S.d)
    print(f"  {algo}: K = {plan.count(S, U)}")

# plan once, call many: the compiled plan is reusable and never retraces
plan = build_plan(MatchSpec(algo="sbm", capacity="exact"), S.n, U.n, S.d)
pairs, count = plan.pairs(S, U)
print("  pairs:", sorted(pairs_to_set(pairs, U.n, S.n)),
      "(ids = s_idx *", U.n, "+ u_idx)")

# --- 2. the paper's synthetic benchmark at small scale ---------------------
S1, U1 = paper_workload(seed=0, n_total=10_000, alpha=1.0)
plan1 = build_plan(MatchSpec(algo="sbm"), S1.n, U1.n, S1.d)
k = plan1.count(S1, U1)
print(f"\n== paper workload N=1e4 alpha=1: K = {k} "
      f"(E[K] ~ alpha*N/2 = {1.0 * 10_000 / 2:.0f}) ==")

# backend is a config value: the same spec on the Pallas kernels
# (interpret=True runs the kernel bodies on CPU; drop it on a real TPU)
pplan = build_plan(MatchSpec(algo="sbm", backend="pallas", interpret=True),
                   S1.n, U1.n, S1.d)
assert pplan.count(S1, U1) == k
print("   pallas backend agrees (interpret mode)")

# --- 3. dynamic DDM (paper §3): move a region, get pair deltas -------------
svc = DDMService(S1, U1)          # rides the same engine (ITM plan, grow)
svc.connect()
added, removed = svc.update_region("upd", 0, 100.0, 400.0)
print(f"\n== dynamic update of one region: +{len(added)} / "
      f"-{len(removed)} overlap pairs ==")

# --- 4. the same matcher planning block-sparse attention -------------------
from repro.sparse.planner import BlockPlan, block_windows  # noqa: E402

plan = BlockPlan(seq_len=4096, block_q=128, block_kv=128, window=1024,
                 sink_blocks=1)
starts, ends = block_windows(plan)
print(f"\n== DDM as attention planner: {plan.nq} query blocks, "
      f"window rows like q-block 16 -> kv[{starts[16]}:{ends[16]}) ==")
