"""Quickstart: the DDM matching service in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (DDMService, make_regions, match_count,
                        match_pairs, paper_workload, pairs_to_set)

# --- 1. the region matching problem (paper Fig. 3) -------------------------
S = make_regions([[1.0, 1.0], [4.0, 0.5], [2.5, 2.0]],
                 [[3.0, 3.0], [6.0, 2.5], [5.0, 4.0]])   # 3 subscriptions
U = make_regions([[2.0, 2.0], [4.5, 1.0]],
                 [[4.0, 4.0], [5.5, 3.0]])               # 2 updates

print("== 2-D matching, all algorithms agree ==")
for algo in ("bfm", "sbm", "itm"):
    print(f"  {algo}: K = {match_count(S, U, algo=algo)}")

pairs, count = match_pairs(S, U, max_pairs=8, algo="sbm")
print("  pairs:", sorted(pairs_to_set(pairs, U.n)),
      "(ids = s_idx *", U.n, "+ u_idx)")

# --- 2. the paper's synthetic benchmark at small scale ---------------------
S1, U1 = paper_workload(seed=0, n_total=10_000, alpha=1.0)
k = match_count(S1, U1, algo="sbm")
print(f"\n== paper workload N=1e4 alpha=1: K = {k} "
      f"(E[K] ~ alpha*N/2 = {1.0 * 10_000 / 2:.0f}) ==")

# --- 3. dynamic DDM (paper §3): move a region, get pair deltas -------------
svc = DDMService(S1, U1)
svc.connect()
added, removed = svc.update_region("upd", 0, 100.0, 400.0)
print(f"\n== dynamic update of one region: +{len(added)} / "
      f"-{len(removed)} overlap pairs ==")

# --- 4. the same matcher planning block-sparse attention -------------------
from repro.sparse.planner import BlockPlan, block_windows  # noqa: E402

plan = BlockPlan(seq_len=4096, block_q=128, block_kv=128, window=1024,
                 sink_blocks=1)
starts, ends = block_windows(plan)
print(f"\n== DDM as attention planner: {plan.nq} query blocks, "
      f"window rows like q-block 16 -> kv[{starts[16]}:{ends[16]}) ==")
