"""Property tests for the exact two-pass pair enumeration and the
batched d-dimensional dynamic DDM engine.

Two-pass enumeration (core.sbm / core.dd_match): exact pair sets and
counts vs the numpy brute-force oracle for d ∈ {1, 2, 3}, including
empty sets, duplicate endpoints (integer-grid regime), truncation
reporting, and the long-region workloads whose data-dependent window
made the old bounded-window path blow up.

Batched service (core.dynamic): ``update_regions`` deltas and ledger
must be identical to a sequence of single ``update_region`` calls on
randomized workloads, including zero-churn and duplicate-index batches.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (DDMService, Regions, make_regions, pairs_to_set,
                        paper_workload)
from repro.core import brute, itm, sbm

from proputils import interval_cases, oracle_mask, plan_count, plan_pairs


def _regions(s_lo, s_hi, u_lo, u_hi):
    return make_regions(s_lo, s_hi), make_regions(u_lo, u_hi)


# ---------------------------------------------------------------------------
# two-pass enumeration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", (1, 2, 3))
@pytest.mark.parametrize("algo", ("sbm", "itm"))
def test_twopass_pairs_match_oracle_dd(algo, d):
    for seed, s_lo, s_hi, u_lo, u_hi in interval_cases(
            n_cases=8, d=d, max_n=150, max_m=150, include_empty=True):
        S, U = _regions(s_lo, s_hi, u_lo, u_hi)
        mask = oracle_mask(s_lo, s_hi, u_lo, u_hi)
        want = {int(a) * max(U.n, 1) + int(b)
                for a, b in zip(*np.nonzero(mask))}
        cap = max(int(mask.sum()), 1) + 3
        pairs, count = plan_pairs(S, U, max_pairs=cap, algo=algo)
        assert int(count) == len(want), f"seed={seed} d={d} algo={algo}"
        assert pairs.shape == (cap, 2)
        assert pairs_to_set(pairs, max(U.n, 1)) == want, \
            f"seed={seed} d={d} algo={algo}"


def test_twopass_count_equals_per_sub_counts():
    """Emit counts (type A + type B decomposition) must agree with the
    binary-search per-subscription counts they are derived from."""
    for seed, s_lo, s_hi, u_lo, u_hi in interval_cases(n_cases=10, d=1):
        S, U = _regions(s_lo, s_hi, u_lo, u_hi)
        per_sub = int(np.sum(np.asarray(sbm.sbm_count_per_sub(S, U)),
                             dtype=np.int64))
        _, count = sbm.sbm_pairs(S, U, max_pairs=1)
        assert count == per_sub, seed


def test_twopass_no_window_blowup_on_long_regions():
    """A few road-length update regions made the old window ≈ m (the
    whole sorted array) and its (n, window) mask explode; the two-pass
    path emits exactly K with a buffer of exactly K."""
    n = 5000
    s_lo = np.linspace(0.0, 1e6, n, dtype=np.float32)[:, None]
    s_hi = s_lo + 1.0
    # 4 updates spanning the whole domain + many tiny non-matching ones
    u_lo = np.concatenate([np.zeros((4, 1)),
                           np.full((2000, 1), 2e6)]).astype(np.float32)
    u_hi = np.concatenate([np.full((4, 1), 2e6),
                           np.full((2000, 1), 2e6 + 1)]).astype(np.float32)
    S, U = _regions(s_lo, s_hi, u_lo, u_hi)
    k = 4 * n
    pairs, count = plan_pairs(S, U, max_pairs=k, algo="sbm")
    assert int(count) == k
    assert pairs_to_set(pairs, U.n) == {
        s * U.n + u for s in range(n) for u in range(4)}


def test_twopass_truncation_reports_exact_count():
    S, U = paper_workload(seed=9, n_total=500, alpha=50.0)
    true_k = plan_count(S, U, algo="sbm")
    pairs, count = plan_pairs(S, U, max_pairs=7, algo="sbm")
    assert int(count) == true_k and true_k > 7
    arr = np.asarray(pairs)
    assert arr.shape == (7, 2) and (arr >= 0).all()  # buffer full, valid
    # every emitted pair is a true overlap
    s_lo, s_hi = np.asarray(S.lo), np.asarray(S.hi)
    u_lo, u_hi = np.asarray(U.lo), np.asarray(U.hi)
    mask = oracle_mask(s_lo, s_hi, u_lo, u_hi)
    assert all(mask[s, u] for s, u in arr)


def test_count_dd_no_overflow_with_small_max_pairs():
    """The old d>1 path raised OverflowError when the candidate count
    exceeded a user-passed max_pairs; now the exact bound wins."""
    S, U = paper_workload(seed=3, n_total=600, alpha=30.0, d=2)
    want = brute.bfm_count(S, U)
    assert plan_count(S, U, algo="sbm", max_pairs=2) == want
    assert plan_count(S, U, algo="itm", max_pairs=2) == want


def test_itm_count_int64_path_large_counts():
    """ITM enumeration count must not be narrowed to int32 semantics:
    the count is returned as an int64-safe python int."""
    S, U = paper_workload(seed=5, n_total=2000, alpha=50.0)
    _, count = plan_pairs(S, U, max_pairs=8, algo="itm")
    assert isinstance(int(count), int)
    assert int(count) == plan_count(S, U, algo="itm")


# ---------------------------------------------------------------------------
# batched dynamic service
# ---------------------------------------------------------------------------

def _brute_truth(svc: DDMService) -> set[tuple[int, int]]:
    S = Regions(jnp.asarray(svc.s_lo), jnp.asarray(svc.s_hi))
    U = Regions(jnp.asarray(svc.u_lo), jnp.asarray(svc.u_hi))
    mask = np.asarray(brute.bfm_mask(S, U))
    return {(int(a), int(b)) for a, b in zip(*np.nonzero(mask))}


@pytest.mark.parametrize("d", (1, 2, 3))
def test_batched_equals_sequential_updates(d):
    S, U = paper_workload(seed=40 + d, n_total=200, alpha=6.0, d=d)
    svc_b = DDMService(S, U)
    svc_s = DDMService(S, U)
    assert svc_b.connect() == svc_s.connect() == _brute_truth(svc_b)
    rng = np.random.default_rng(d)
    for step, kind in enumerate(("sub", "upd", "sub")):
        b = int(rng.integers(1, 40))
        idx = rng.choice(100, size=b, replace=False)
        lo = rng.uniform(0, 9e5, (b, d)).astype(np.float32)
        hi = lo + rng.uniform(1.0, 5e4, (b, d)).astype(np.float32)
        added_b, removed_b = svc_b.update_regions(kind, idx, lo, hi)
        added_s, removed_s = set(), set()
        for i in range(b):
            a, r = svc_s.update_region(kind, int(idx[i]), lo[i], hi[i])
            added_s |= a
            removed_s |= r
        assert added_b == added_s, (d, step, kind)
        assert removed_b == removed_s, (d, step, kind)
        assert svc_b.pairs == svc_s.pairs == _brute_truth(svc_b)


def test_batched_zero_churn_is_noop():
    S, U = paper_workload(seed=50, n_total=100, alpha=2.0, d=2)
    svc = DDMService(S, U)
    before = set(svc.connect())
    added, removed = svc.update_regions(
        "sub", np.zeros((0,), np.int64), np.zeros((0, 2)),
        np.zeros((0, 2)))
    assert added == set() and removed == set()
    assert svc.pairs == before


def test_batched_duplicate_index_last_wins():
    S, U = paper_workload(seed=51, n_total=120, alpha=5.0)
    svc_b = DDMService(S, U)
    svc_s = DDMService(S, U)
    svc_b.connect()
    svc_s.connect()
    idx = np.array([3, 7, 3])          # region 3 moved twice
    lo = np.array([[10.0], [20.0], [5000.0]], np.float32)
    hi = lo + 300.0
    added_b, removed_b = svc_b.update_regions("sub", idx, lo, hi)
    for i in range(3):
        svc_s.update_region("sub", int(idx[i]), lo[i], hi[i])
    # final state identical; batched deltas are the net of the sequence
    assert svc_b.pairs == svc_s.pairs == _brute_truth(svc_b)
    assert not (added_b & removed_b)


def test_batched_moves_onto_empty_opposite_set():
    S, _ = paper_workload(seed=52, n_total=60, alpha=2.0, d=2)
    empty = make_regions(np.zeros((0, 2)), np.zeros((0, 2)))
    svc = DDMService(S, empty)
    assert svc.connect() == set()
    added, removed = svc.update_regions(
        "sub", np.array([0, 1]),
        np.zeros((2, 2), np.float32), np.ones((2, 2), np.float32))
    assert added == set() and removed == set()
    assert svc.pairs == set()


def test_batched_duplicate_endpoints_grid(d=2):
    """Integer-grid coordinates (many exact ties) through connect +
    batched churn; ledger must track the brute-force truth exactly."""
    rng = np.random.default_rng(53)
    n, m = 80, 90
    s_lo = rng.integers(0, 12, (n, d)).astype(np.float32)
    s_hi = s_lo + rng.integers(1, 5, (n, d)).astype(np.float32)
    u_lo = rng.integers(0, 12, (m, d)).astype(np.float32)
    u_hi = u_lo + rng.integers(1, 5, (m, d)).astype(np.float32)
    svc = DDMService(make_regions(s_lo, s_hi), make_regions(u_lo, u_hi))
    assert svc.connect() == _brute_truth(svc)
    idx = rng.choice(m, size=25, replace=False)
    lo = rng.integers(0, 12, (25, d)).astype(np.float32)
    hi = lo + rng.integers(1, 5, (25, d)).astype(np.float32)
    svc.update_regions("upd", idx, lo, hi)
    assert svc.pairs == _brute_truth(svc)


def test_itm_query_pairs_dd_matches_brute():
    S, U = paper_workload(seed=54, n_total=160, alpha=8.0, d=3)
    T = itm.build_tree(S)
    counts0 = itm.itm_query_counts(T, U.lo[:, 0], U.hi[:, 0])
    cap = max(int(np.max(np.asarray(counts0))), 1)
    ids, counts = itm.itm_query_pairs_dd(T, S.lo, S.hi, U.lo, U.hi, cap)
    ids, counts = np.asarray(ids), np.asarray(counts)
    mask = oracle_mask(np.asarray(S.lo), np.asarray(S.hi),
                       np.asarray(U.lo), np.asarray(U.hi))
    for u in range(U.n):
        want = set(np.nonzero(mask[:, u])[0].tolist())
        assert set(ids[u][ids[u] >= 0].tolist()) == want, u
        assert counts[u] == len(want), u
