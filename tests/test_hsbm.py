"""Hybrid grid+SBM (``algo="hsbm"``): exactness, geometry extremes,
PairsResult contract, spec validation, and zero steady-state retrace.

The tentpole's correctness claim is that replacing pass 1's global
endpoint sorts with coarse grid bucketing + per-cell segmented sorts is
*exact*: the hybrid must be set-identical to the flat SBM path and the
numpy brute oracle for d ∈ {1, 2, 3}, on every backend available on
CPU and under every capacity policy — including the adversarial
geometries (everything in one cell; fully disjoint cells) and the
conservative-spill boundary cases the suffix windows exist for.
"""
import numpy as np
import pytest

import repro.core.grid as grid
from repro.core import (MatchSpec, PairsResult, build_plan, make_regions,
                        paper_workload, pairs_to_set)
from repro.core import sbm
from repro.kernels import ops

from proputils import interval_cases, oracle_mask

BACKENDS_ON_CPU = ("xla", "pallas")


def _spec(backend, **kw):
    kw.setdefault("capacity", "grow")
    return MatchSpec(algo="hsbm", backend=backend, block=512,
                     interpret=(backend == "pallas"), **kw)


def _oracle_set(S, U):
    mask = oracle_mask(np.asarray(S.lo), np.asarray(S.hi),
                       np.asarray(U.lo), np.asarray(U.hi))
    return {int(a) * max(U.n, 1) + int(b)
            for a, b in zip(*np.nonzero(mask))}


# ---------------------------------------------------------------------------
# exactness vs the brute oracle (the tentpole acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS_ON_CPU)
@pytest.mark.parametrize("d", (1, 2, 3))
def test_hsbm_matches_oracle(backend, d):
    for seed, s_lo, s_hi, u_lo, u_hi in interval_cases(
            n_cases=6, d=d, max_n=150, max_m=150, include_empty=True):
        S = make_regions(s_lo, s_hi)
        U = make_regions(u_lo, u_hi)
        want = _oracle_set(S, U)
        plan = build_plan(_spec(backend), S.n, U.n, d)
        assert plan.count(S, U) == len(want), f"seed={seed} d={d}"
        res, k = plan.pairs(S, U)
        assert isinstance(res, PairsResult), f"seed={seed}"
        assert k == len(want), f"seed={seed} d={d} {backend}"
        assert pairs_to_set(res, max(U.n, 1), max(S.n, 1)) == want, \
            f"seed={seed} d={d} {backend}"


@pytest.mark.parametrize("capacity", ("exact", "fixed", "grow"))
@pytest.mark.parametrize("backend", BACKENDS_ON_CPU)
def test_hsbm_capacity_policies_match_flat_sbm(backend, capacity):
    """hsbm ≡ sbm under every capacity policy (set-identical, same K)."""
    for d, alpha in ((1, 4.0), (2, 60.0), (3, 350.0)):
        S, U = paper_workload(seed=70 + d, n_total=400, alpha=alpha, d=d)
        ref = build_plan(MatchSpec(algo="sbm"), S.n, U.n, d)
        want_k = ref.count(S, U)
        assert want_k > 0, (d, alpha)       # the workload must be dense
        ref_set = pairs_to_set(ref.pairs(S, U)[0], U.n, S.n)
        kw = {"max_pairs": want_k + 5} if capacity == "fixed" else {}
        plan = build_plan(_spec(backend, capacity=capacity, **kw),
                          S.n, U.n, d)
        res, k = plan.pairs(S, U)
        assert k == want_k, (d, backend, capacity)
        assert isinstance(res, PairsResult)
        assert pairs_to_set(res, U.n, S.n) == ref_set, \
            (d, backend, capacity)
        plan.validate_pairs(res, count=k)


# ---------------------------------------------------------------------------
# adversarial geometries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS_ON_CPU)
def test_hsbm_all_regions_in_one_cell(backend):
    """Identical coordinates: every region lands in cell 0 and the
    per-cell capacity must absorb the whole set (cap == n)."""
    n = 96
    lo = np.full((n, 1), 5.0, np.float32)
    hi = np.full((n, 1), 6.0, np.float32)
    S, U = make_regions(lo, hi), make_regions(lo + 0.5, hi + 0.5)
    plan = build_plan(_spec(backend), S.n, U.n, 1)
    res, k = plan.pairs(S, U)
    assert k == n * n
    assert pairs_to_set(res, U.n, S.n) == _oracle_set(S, U)


@pytest.mark.parametrize("backend", BACKENDS_ON_CPU)
def test_hsbm_fully_disjoint_cells(backend):
    """Far-apart unit intervals: K = n (self-pairs only), with an
    explicit ncells override so the geometry actually buckets and the
    test exercises the per-cell path, not the degenerate single cell
    (the auto heuristic collapses a 512-region probe to one cell)."""
    n = 256
    base = (np.arange(n, dtype=np.float32) * 1000.0)[:, None]
    S = make_regions(base, base + 1.0)
    U = make_regions(base + 0.25, base + 0.75)
    g = grid.hsbm_geometry(np.asarray(S.lo[:, 0]), np.asarray(S.hi[:, 0]),
                           np.asarray(U.lo[:, 0]), np.asarray(U.hi[:, 0]),
                           ncells=32)
    assert g.ncells == 32
    plan = build_plan(_spec(backend, hsbm_ncells=32), S.n, U.n, 1)
    assert plan.count(S, U) == n
    res, k = plan.pairs(S, U)
    assert k == n
    assert pairs_to_set(res, U.n, S.n) == {i * n + i for i in range(n)}


def test_hsbm_ncells_override_is_exact():
    """An explicit hsbm_ncells knob changes geometry, never results."""
    S, U = paper_workload(seed=77, n_total=2000, alpha=8.0)
    want = build_plan(MatchSpec(algo="sbm"), S.n, U.n, 1).count(S, U)
    for nc in (1, 4, 64, 1024):
        plan = build_plan(_spec("xla", hsbm_ncells=nc), S.n, U.n, 1)
        assert plan.count(S, U) == want, nc


def test_hsbm_geometry_blowup_guard():
    """Skewed data (one hot cell + far outlier) must not let the padded
    tables blow past the linear-in-(n+m) row bound."""
    rng = np.random.default_rng(5)
    lo = np.concatenate([rng.uniform(0.0, 1.0, 4000),
                         np.array([1e6])]).astype(np.float32)[:, None]
    hi = lo + np.float32(0.5)
    S, U = make_regions(lo, hi), make_regions(lo, hi)
    g = grid.hsbm_geometry(lo[:, 0], hi[:, 0], lo[:, 0], hi[:, 0])
    rows = g.ncells * (g.cap_s + g.suf_s + g.cap_u + g.suf_u)
    assert rows <= max(4 * (S.n + U.n), 1 << 16)
    plan = build_plan(_spec("xla"), S.n, U.n, 1)
    assert plan.count(S, U) == len(_oracle_set(S, U))


# ---------------------------------------------------------------------------
# PairsResult contract + emit routes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("route", ("resident", "streaming", "csr", "xla"))
def test_hsbm_pallas_routes_identical(route):
    S, U = paper_workload(seed=79, n_total=1500, alpha=50.0)
    want = _oracle_set(S, U)
    plan = build_plan(_spec("pallas", emit_route=route), S.n, U.n, 1)
    res, k = plan.pairs(S, U)
    assert k == len(want), route
    assert isinstance(res, PairsResult), route
    if route == "csr":
        assert isinstance(res, ops.HsbmCSRPairs)
        assert res.nbytes < res.dense_nbytes
    assert ops.last_emit_route() == route
    assert pairs_to_set(res, U.n, S.n) == want, route
    # windows() must reassemble to the same dense buffer
    got = np.full((res.cap, 2), -1, np.int32)
    for w0, win in res.windows(chunk=257):
        got[w0:w0 + win.shape[0]] = win
    assert pairs_to_set(got, U.n, S.n) == want, route


# ---------------------------------------------------------------------------
# spec validation + retrace discipline (satellites)
# ---------------------------------------------------------------------------

def test_spec_rejects_csr_route_for_multidim():
    """emit_route='csr' with d > 1 is a spec-time error: the d-dim
    verify pass gathers from a dense dim-0 candidate buffer, which a
    lazy CSR view never materializes."""
    with pytest.raises(ValueError, match="csr"):
        MatchSpec(algo="sbm", backend="pallas", emit_route="csr", d=2)
    with pytest.raises(ValueError, match="csr"):
        MatchSpec(algo="hsbm", backend="pallas", emit_route="csr", d=3)
    # d=1 (or unspecified d, checked again at plan build) stays legal
    MatchSpec(algo="hsbm", backend="pallas", emit_route="csr", d=1)
    spec = MatchSpec(algo="hsbm", backend="pallas", emit_route="csr")
    with pytest.raises(ValueError, match="csr"):
        build_plan(spec, 64, 64, 2, key=("hsbm-csr-d2",))


def test_spec_d_must_match_plan_d():
    spec = MatchSpec(algo="hsbm", d=2)
    with pytest.raises(ValueError, match="d=2"):
        build_plan(spec, 64, 64, 3, key=("hsbm-d-mismatch",))


@pytest.mark.parametrize("backend", BACKENDS_ON_CPU)
def test_hsbm_zero_steady_state_retrace(backend):
    """Fresh same-shape, same-distribution workloads re-measure the
    geometry on the host but must resolve to the same statics — the
    steady state never retraces."""
    plan = build_plan(_spec(backend, capacity="grow"), 600, 600, 1)
    for i in range(2):                    # warm both executables
        S, U = paper_workload(seed=90 + i, n_total=1200, alpha=3.0)
        plan.count(S, U)
        plan.pairs(S, U)
    warm = plan.traces
    for i in range(2, 5):
        S, U = paper_workload(seed=90 + i, n_total=1200, alpha=3.0)
        k = plan.count(S, U)
        res, kp = plan.pairs(S, U)
        assert k == kp and k > 0
    assert plan.traces == warm, (backend, plan.traces, warm)


def test_hsbm_distributed_backend_rejected():
    with pytest.raises(ValueError):
        build_plan(MatchSpec(algo="hsbm", backend="distributed"),
                   512, 512, 1, key=("hsbm-dist",)).count(
            *paper_workload(seed=1, n_total=1024, alpha=1.0))
