"""Checkpointing (atomic, sharded, elastic reshard) + fault-tolerant
training runtime.

Key property: a run with injected node failures + restarts is
*bit-identical* to an uninterrupted run — deterministic data pipeline ×
atomic checkpoints × pure train step.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.sharded import (AsyncSaver, latest_step, restore,
                                      save)
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (13, 5)),
            "nested": {"b": jnp.arange(7, dtype=jnp.int32),
                       "c": jax.random.normal(k, (4, 3, 2))},
            "scalar": jnp.float32(3.25)}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    save(tmp_path, 3, tree, n_shards=1)
    assert latest_step(tmp_path) == 3
    out = restore(tmp_path, 3, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_reshard_roundtrip(tmp_path):
    """Save with P shards, restore with P' — the DDM-planned transfer."""
    tree = _tree()
    for p_old, p_new in [(4, 3), (3, 4), (1, 5), (5, 1), (2, 2)]:
        d = tmp_path / f"{p_old}_{p_new}"
        save(d, 1, tree, n_shards=p_old)
        out = restore(d, 1, tree, n_shards_new=p_new)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_saver(tmp_path):
    tree = _tree()
    s = AsyncSaver()
    s.save(tmp_path, 7, tree)
    s.wait()
    out = restore(tmp_path, 7, tree)
    np.testing.assert_array_equal(np.asarray(tree["a"]),
                                  np.asarray(out["a"]))


def test_data_pipeline_deterministic_and_host_sharded():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=8, seed=5,
                     n_hosts=4)
    pipe = SyntheticTokens(cfg)
    b1 = pipe.global_batch(3)
    b2 = pipe.global_batch(3)
    np.testing.assert_array_equal(b1, b2)          # deterministic
    assert b1.shape == (8, 17)
    # host shards are disjoint parts of the global batch
    h0 = pipe.batch(3, 0)
    np.testing.assert_array_equal(b1[:2], h0)
    assert not np.array_equal(pipe.batch(3, 0), pipe.batch(3, 1))
    assert not np.array_equal(pipe.batch(3, 0), pipe.batch(4, 0))


def _mk_trainer(tmp_path, ckpt_every=2):
    mcfg = dataclasses.replace(get_smoke_config("qwen2_0_5b"),
                               remat=False)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=ckpt_every)
    dcfg = DataConfig(vocab=mcfg.vocab, seq_len=16, global_batch=4)
    return Trainer(mcfg, ocfg, tcfg, dcfg)


def test_trainer_loss_decreases(tmp_path):
    tr = _mk_trainer(tmp_path)
    losses = []
    tr.run(8, on_step=lambda s, m: losses.append(float(m["loss"])))
    assert losses[-1] < losses[0]


def test_failure_restart_is_bit_identical(tmp_path):
    """Crash at step 5, restart from ckpt → same final params as a
    straight run (the fault-tolerance contract)."""
    tr1 = _mk_trainer(tmp_path / "a", ckpt_every=2)
    p1, o1, m1 = tr1.run(7)

    tr2 = _mk_trainer(tmp_path / "b", ckpt_every=2)
    p2, o2, m2 = tr2.run_resilient(7, failures=(5,))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m1["loss"]) == float(m2["loss"])


def test_double_failure_restart(tmp_path):
    tr1 = _mk_trainer(tmp_path / "a", ckpt_every=3)
    p1, _, _ = tr1.run(9)
    tr2 = _mk_trainer(tmp_path / "b", ckpt_every=3)
    p2, _, _ = tr2.run_resilient(9, failures=(4, 8))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
