"""Dynamic DDM service (paper §3) + multi-device SBM (paper §4).

The distributed test re-execs in a subprocess with
``--xla_force_host_platform_device_count=8`` so the main test process
keeps the real single-device view (per launch policy, only dryrun.py and
explicitly-distributed entry points fake the device count).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp

from repro.core import paper_workload, DDMService, brute

from proputils import plan_count
from repro.core.regions import Regions


def test_dynamic_service_full_lifecycle():
    S, U = paper_workload(seed=21, n_total=300, alpha=5.0)
    svc = DDMService(S, U)
    pairs = svc.connect()
    assert len(pairs) == plan_count(S, U, algo="bfm")

    rng = np.random.default_rng(0)
    for step in range(12):
        kind = "sub" if step % 2 == 0 else "upd"
        idx = int(rng.integers(0, 300 // 2))
        lo = float(rng.uniform(0, 9e5))
        hi = lo + float(rng.uniform(1.0, 5e3))
        added, removed = svc.update_region(kind, idx, lo, hi)
        assert not (added & removed)
        # ledger always matches a from-scratch brute-force match
        S2 = Regions(jnp.asarray(svc.s_lo), jnp.asarray(svc.s_hi))
        U2 = Regions(jnp.asarray(svc.u_lo), jnp.asarray(svc.u_hi))
        mask = np.asarray(brute.bfm_mask(S2, U2))
        truth = {(int(a), int(b)) for a, b in zip(*np.nonzero(mask))}
        assert svc.pairs == truth, f"step={step}"


def test_dynamic_delta_is_local():
    """Only pairs involving the moved region may change (paper §3: a
    region update triggers at most O(m) new overlaps)."""
    S, U = paper_workload(seed=22, n_total=200, alpha=10.0)
    svc = DDMService(S, U)
    svc.connect()
    added, removed = svc.update_region("upd", 5, 10.0, 500.0)
    assert all(u == 5 for _, u in added | removed)


DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.core import MatchSpec, build_plan, paper_workload
    for seed, n, a in [(0, 2000, 10.0), (1, 5000, 1.0), (2, 4096, 100.0),
                       (3, 130, 0.01), (4, 999, 1.0)]:
        S, U = paper_workload(seed=seed, n_total=n, alpha=a)
        ref = build_plan(MatchSpec(algo="sbm"), S.n, U.n, 1).count(S, U)
        dplan = build_plan(MatchSpec(algo="sbm", backend="distributed"),
                           S.n, U.n, 1)
        got = dplan.count(S, U)
        assert ref == got, (seed, ref, got)
    print("DIST_OK")
""")


def test_distributed_sbm_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", DIST_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "DIST_OK" in out.stdout
