"""MatchSpec → MatchPlan engine: cross-algo/backend parity,
zero-retrace plan reuse, capacity policies, and shim retirement.

For every algo and every backend available on CPU (``xla``,
interpret-mode ``pallas``), ``plan.pairs()`` must return the exact
oracle pair set on randomized d ∈ {1, 2, 3} workloads, a repeated call
must never retrace (checked via the plan's trace counter), and the
removed pre-engine entry points must stay removed.
"""
import numpy as np
import pytest

from repro.core import (ALGOS, DDMService, MatchSpec, build_plan,
                        koln_like_workload, make_regions, paper_workload,
                        pairs_to_set)
from repro.core import brute
import repro.core as core_pkg
import repro.core.dd_match as dd_match_mod
import repro.core.distributed as distributed_mod

from proputils import interval_cases, oracle_mask, plan_pairs

BACKENDS_ON_CPU = ("xla", "pallas")


def _spec(algo, backend, **kw):
    """CPU-testable spec: small Pallas tiles, interpret mode."""
    kw.setdefault("capacity", "grow")
    return MatchSpec(algo=algo, backend=backend, ts=64, tu=64, block=512,
                     interpret=(backend == "pallas"), **kw)


def _ref_pairs_set(S, U, algo, k):
    """Reference pair set via the fixed-capacity xla plan."""
    pairs, count = plan_pairs(S, U, max(k, 1) + 3, algo=algo)
    return pairs_to_set(pairs, max(U.n, 1), max(S.n, 1)), int(count)


# ---------------------------------------------------------------------------
# cross-backend parity (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS_ON_CPU)
@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("d", (1, 2, 3))
def test_plan_pairs_match_reference(algo, backend, d):
    for seed, s_lo, s_hi, u_lo, u_hi in interval_cases(
            n_cases=3, d=d, max_n=120, max_m=120):
        S = make_regions(s_lo, s_hi)
        U = make_regions(u_lo, u_hi)
        want_k = int(oracle_mask(s_lo, s_hi, u_lo, u_hi).sum())
        want_set, ref_k = _ref_pairs_set(S, U, algo, want_k)
        assert ref_k == want_k, f"seed={seed}"
        plan = build_plan(_spec(algo, backend), S.n, U.n, d)
        assert plan.count(S, U) == want_k, f"seed={seed}"
        pairs, k = plan.pairs(S, U)
        assert k == want_k, f"seed={seed} {algo}/{backend} d={d}"
        assert pairs_to_set(pairs, U.n, S.n) == want_set, \
            f"seed={seed} {algo}/{backend} d={d}"


@pytest.mark.parametrize("backend", BACKENDS_ON_CPU)
@pytest.mark.parametrize("algo", ALGOS)
def test_plan_zero_retrace_on_repeat(algo, backend):
    S, U = paper_workload(seed=31, n_total=240, alpha=4.0, d=2)
    plan = build_plan(_spec(algo, backend, p=4), S.n, U.n, S.d)
    pairs1, k1 = plan.pairs(S, U)
    _ = plan.count(S, U)
    warm = plan.traces
    for _ in range(3):
        pairs2, k2 = plan.pairs(S, U)
        _ = plan.count(S, U)
    assert plan.traces == warm, (algo, backend, plan.traces, warm)
    assert k2 == k1
    np.testing.assert_array_equal(np.asarray(pairs1), np.asarray(pairs2))


def test_plan_mask_parity():
    S, U = paper_workload(seed=33, n_total=200, alpha=6.0, d=2)
    want = np.asarray(brute.bfm_mask(S, U))
    for backend in BACKENDS_ON_CPU:
        plan = build_plan(_spec("bfm", backend), S.n, U.n, S.d)
        np.testing.assert_array_equal(np.asarray(plan.mask(S, U)), want)


# ---------------------------------------------------------------------------
# capacity policies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_capacity_policies_identical_pair_sets(algo):
    cases = [paper_workload(seed=61, n_total=300, alpha=5.0),
             paper_workload(seed=62, n_total=200, alpha=3.0, d=2),
             koln_like_workload(seed=63, n_positions=200)]
    for S, U in cases:
        exact = build_plan(_spec(algo, "xla", capacity="exact"),
                           S.n, U.n, S.d)
        grow = build_plan(_spec(algo, "xla", capacity="grow"),
                          S.n, U.n, S.d)
        pe, ke = exact.pairs(S, U)
        pg, kg = grow.pairs(S, U)
        assert ke == kg
        assert pe.shape[0] == max(ke, 1)      # exact: buffer is exactly K
        assert pg.shape[0] >= ke and _ispow2(pg.shape[0])
        assert pairs_to_set(pe, U.n, S.n) == pairs_to_set(pg, U.n, S.n)


def _ispow2(x):
    return x >= 1 and (x & (x - 1)) == 0


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("capacity", ("exact", "grow"))
def test_capacity_policies_edge_regions(algo, capacity):
    empty = make_regions(np.zeros((0, 1)), np.zeros((0, 1)))
    one = make_regions(np.array([[1.0]]), np.array([[4.0]]))
    for S, U, want in ((empty, one, 0), (one, empty, 0),
                       (empty, empty, 0), (one, one, 1)):
        plan = build_plan(_spec(algo, "xla", capacity=capacity),
                          S.n, U.n, 1)
        assert plan.count(S, U) == want, (algo, capacity)
        pairs, k = plan.pairs(S, U)
        assert k == want, (algo, capacity)
        got = pairs_to_set(pairs, max(U.n, 1), max(S.n, 1))
        assert len(got) == want, (algo, capacity)


def test_grow_policy_doubles_and_memoizes():
    S, U = paper_workload(seed=64, n_total=400, alpha=20.0)
    plan = build_plan(
        MatchSpec(algo="sbm", capacity="grow", max_pairs=4), S.n, U.n, 1)
    pairs, k = plan.pairs(S, U)
    assert k > 4 and pairs.shape[0] >= k and _ispow2(pairs.shape[0])
    warm = plan.traces
    pairs2, _ = plan.pairs(S, U)          # steady state: no regrow
    assert plan.traces == warm
    assert pairs2.shape == pairs.shape


def test_fixed_policy_truncates_but_reports_exact():
    S, U = paper_workload(seed=65, n_total=400, alpha=20.0)
    true_k = build_plan(_spec("sbm", "xla"), S.n, U.n, 1).count(S, U)
    plan = build_plan(
        MatchSpec(algo="sbm", capacity="fixed", max_pairs=5), S.n, U.n, 1)
    pairs, k = plan.pairs(S, U)
    assert k == true_k and true_k > 5
    assert pairs.shape == (5, 2)


# ---------------------------------------------------------------------------
# shim retirement + pairs_to_set validation (satellites)
# ---------------------------------------------------------------------------

def test_removed_shims_stay_removed():
    """The pre-engine entry points completed their deprecation cycle;
    they must not resurface on the package or their home modules (the
    repro.analysis lint enforces the same at the source level)."""
    for name in ("match_count", "match_pairs", "distributed_sbm_count"):
        assert not hasattr(core_pkg, name), name
        assert name not in core_pkg.__all__, name
    assert not hasattr(dd_match_mod, "match_count")
    assert not hasattr(dd_match_mod, "match_pairs")
    assert not hasattr(distributed_mod, "distributed_sbm_count")


def test_pairs_to_set_validates_both_sizes():
    good = np.array([[0, 1], [2, 0], [-1, -1]], np.int32)
    assert pairs_to_set(good, 2, 3) == {1, 4}
    with pytest.raises(ValueError):
        pairs_to_set(np.array([[0, 2]], np.int32), 2, 3)   # u out of range
    with pytest.raises(ValueError):
        pairs_to_set(np.array([[3, 1]], np.int32), 2, 3)   # s out of range
    # m-only call keeps the old signature working (u still validated)
    assert pairs_to_set(good, 2) == {1, 4}
    with pytest.raises(ValueError):
        pairs_to_set(np.array([[0, 5]], np.int32), 2)


# ---------------------------------------------------------------------------
# dynamic service rides the same plan
# ---------------------------------------------------------------------------

def test_ddmservice_uses_engine_plan_and_stays_exact():
    S, U = paper_workload(seed=67, n_total=160, alpha=5.0, d=2)
    svc = DDMService(S, U, spec=MatchSpec(algo="itm", capacity="grow",
                                          max_pairs=8))
    svc.connect()
    rng = np.random.default_rng(3)
    for kind in ("sub", "upd", "sub"):
        idx = rng.choice(40, size=9, replace=False)
        lo = rng.uniform(0, 9e5, (9, 2)).astype(np.float32)
        hi = lo + rng.uniform(1.0, 5e4, (9, 2)).astype(np.float32)
        svc.update_regions(kind, idx, lo, hi)
    mask = np.asarray(brute.bfm_mask(
        make_regions(svc.s_lo, svc.s_hi), make_regions(svc.u_lo, svc.u_hi)))
    truth = {(int(a), int(b)) for a, b in zip(*np.nonzero(mask))}
    assert svc.pairs == truth
    assert svc.plan.traces > 0            # the queries ran through the plan
    # cap_hint floors the query capacity when the spec leaves it unset
    svc2 = DDMService(S, U, cap_hint=128,
                      spec=MatchSpec(algo="itm", capacity="grow"))
    assert svc2.spec.max_pairs == 128


def test_exact_policy_skips_count_pass_in_steady_state():
    S, U = paper_workload(seed=68, n_total=300, alpha=5.0)
    plan = build_plan(MatchSpec(algo="itm", capacity="exact"),
                      S.n, U.n, 1)
    p1, k1 = plan.pairs(S, U)             # first call: count + emit
    warm = plan.traces
    p2, k2 = plan.pairs(S, U)             # steady state: emit only
    assert plan.traces == warm and k1 == k2
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_spec_validation():
    with pytest.raises(ValueError):
        MatchSpec(algo="nope")
    with pytest.raises(ValueError):
        MatchSpec(backend="gpu")
    with pytest.raises(ValueError):
        MatchSpec(capacity="fixed")       # fixed requires max_pairs
    with pytest.raises(ValueError):
        MatchSpec(capacity="bounded")
