"""Emit-route policy and cross-route parity (resident/streaming/csr/XLA).

The four emit regimes must be bit-identical on the pairs they decode —
the route is a pure performance decision (``kernels.ops.choose_emit_route``
byte-budget policy), never a semantic one (csr returns a lazy CSRPairs
view; its decoded dense form is the bit-identical object).  These tests
pin each route explicitly (so the kernel under test is the one that
actually runs — ``last_emit_route`` proves it), drive the router across
every byte threshold, and cross the *real* default thresholds with
interpret-mode runs at n+m = 6e5 (past the old ~5.2e5 resident/VMEM
fallback point), 2e6 (upper edge of the streaming route), and 2.2e6
(the csr regime — past every dense Pallas route).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import MatchSpec, build_plan, make_regions, paper_workload
from repro.core.sbm import sbm_pairs
from repro.kernels import ops
from repro.kernels.emit import DEF_BLOCK

from proputils import interval_cases


# ---------------------------------------------------------------------------
# route policy (pure, no kernels)
# ---------------------------------------------------------------------------

def test_route_policy_thresholds_exact():
    """The router flips exactly at its published byte thresholds."""
    e = 8192
    n = m = e // 2
    need = ops.emit_route_bytes(n, m)
    assert need["resident"] == 4 * (3 * (e + 1) + e)
    assert need["streaming"] == 4 * e + 2 * 8 * (DEF_BLOCK + 256) * 4
    # resident/streaming boundary
    assert ops.choose_emit_route(n, m, budget=need["resident"]) \
        == "resident"
    assert ops.choose_emit_route(n, m, budget=need["resident"] - 1) \
        == "streaming"
    assert need["csr"] == 4 * (8 * (DEF_BLOCK + 256) + 2 * DEF_BLOCK)
    # streaming/csr boundary (csr is constant-footprint, so it backstops
    # streaming at any size where the window alone fits)
    assert ops.choose_emit_route(n, m, budget=need["streaming"]) \
        == "streaming"
    assert ops.choose_emit_route(n, m, budget=need["streaming"] - 1) \
        == "csr"
    # csr/xla boundary
    assert ops.choose_emit_route(n, m, budget=need["csr"]) == "csr"
    assert ops.choose_emit_route(n, m, budget=need["csr"] - 1) == "xla"
    # dense-only callers skip csr entirely
    assert ops.choose_emit_route(n, m, budget=need["streaming"] - 1,
                                 dense_only=True) == "xla"


def test_route_policy_default_budget_regimes():
    """Default 8 MiB budget: the sizes the paper regime cares about."""
    assert ops.choose_emit_route(1024, 1024) == "resident"
    assert ops.choose_emit_route(250_000, 250_000) == "resident"  # 5e5
    assert ops.choose_emit_route(300_000, 300_000) == "streaming"  # 6e5
    assert ops.choose_emit_route(500_000, 500_000) == "streaming"  # 1e6
    assert ops.choose_emit_route(1_000_000, 1_000_000) == "streaming"
    assert ops.choose_emit_route(1_100_000, 1_100_000) == "csr"  # 2.2e6
    assert ops.choose_emit_route(5_000_000, 5_000_000) == "csr"  # 1e7
    assert ops.choose_emit_route(50_000_000, 50_000_000) == "csr"  # 1e8
    # without the lazy view the policy still falls back to XLA
    assert ops.choose_emit_route(1_100_000, 1_100_000,
                                 dense_only=True) == "xla"


def test_route_rejects_unknown():
    S, U = paper_workload(seed=3, n_total=64, alpha=1.0)
    with pytest.raises(ValueError, match="route"):
        ops.twopass_pairs_pallas(S, U, 8, route="vmem", interpret=True)
    with pytest.raises(ValueError, match="emit_route"):
        MatchSpec(backend="pallas", emit_route="vmem")


# ---------------------------------------------------------------------------
# pinned-route parity properties
# ---------------------------------------------------------------------------

def test_pinned_routes_bitexact_property():
    """resident == streaming == xla, slot for slot, across regimes:
    dense/sparse overlap, duplicate integer endpoints, saturated caps
    (cap < K) and all-pad tails (cap >> K)."""
    for seed, s_lo, s_hi, u_lo, u_hi in interval_cases(n_cases=5, d=1):
        S = make_regions(s_lo, s_hi)
        U = make_regions(u_lo, u_hi)
        _, k = sbm_pairs(S, U, 1)
        for cap in (max(k // 2, 1), k + 257):   # saturated / all-pad tail
            want_p, want_c = sbm_pairs(S, U, cap)
            for route in ("resident", "streaming", "csr", "xla"):
                got_p, got_c = ops.twopass_pairs_pallas(
                    S, U, cap, interpret=True, route=route)
                assert ops.last_emit_route() == route, (seed, cap)
                assert got_c == want_c, (seed, cap, route)
                np.testing.assert_array_equal(
                    np.asarray(got_p), np.asarray(want_p),
                    err_msg=f"seed={seed} cap={cap} route={route}")


def test_auto_route_follows_budget():
    """The auto router actually takes the route the policy picks.

    Size chosen so the streaming footprint (permutations + the fixed
    ~48 KiB double-buffer window) is below the resident footprint —
    true from n+m ≈ 4e3 up; below that the policy never picks
    streaming because the window alone outweighs the full tables.
    """
    S, U = paper_workload(seed=9, n_total=16_384, alpha=0.5)
    need = ops.emit_route_bytes(S.n, U.n)
    assert need["streaming"] < need["resident"]
    want_p, want_c = sbm_pairs(S, U, 64)
    for budget, expect in ((need["resident"], "resident"),
                           (need["resident"] - 1, "streaming"),
                           (need["streaming"] - 1, "csr"),
                           (need["csr"] - 1, "xla")):
        got_p, got_c = ops.twopass_pairs_pallas(
            S, U, 64, interpret=True, budget=budget)
        assert ops.last_emit_route() == expect, budget
        assert got_c == want_c
        np.testing.assert_array_equal(np.asarray(got_p),
                                      np.asarray(want_p))


def test_emit_empty_grid_and_empty_sets():
    """max_pairs == 0 short-circuits to (0, 2) before pallas_call."""
    S, U = paper_workload(seed=11, n_total=100, alpha=1.0)
    for route in ("resident", "streaming", "csr", "xla"):
        pairs, count = ops.twopass_pairs_pallas(S, U, 0, interpret=True,
                                                route=route)
        assert tuple(pairs.shape) == (0, 2) and count > 0  # K still exact
    empty = make_regions(np.zeros((0, 1)), np.zeros((0, 1)))
    for route in ("resident", "streaming", "csr", "auto"):
        pairs, count = ops.twopass_pairs_pallas(empty, U, 5,
                                                interpret=True,
                                                route=route)
        assert count == 0 and pairs.shape == (5, 2)
        assert (np.asarray(pairs) == -1).all()
        assert ops.last_emit_route() is None


# ---------------------------------------------------------------------------
# engine surface: MatchSpec pins / inspects the route
# ---------------------------------------------------------------------------

def test_engine_route_pin_and_inspection():
    S, U = paper_workload(seed=13, n_total=1024, alpha=3.0)
    want = build_plan(MatchSpec(algo="sbm", capacity="exact"),
                      S.n, U.n, S.d).pairs(S, U)
    for route in ("resident", "streaming", "csr", "xla"):
        spec = MatchSpec(algo="sbm", backend="pallas", capacity="exact",
                         emit_route=route, interpret=True)
        plan = build_plan(spec, S.n, U.n, S.d)
        assert plan.emit_route() == route
        pairs, k = plan.pairs(S, U)
        assert k == want[1]
        np.testing.assert_array_equal(np.asarray(pairs),
                                      np.asarray(want[0]))
        if route != "xla":
            assert ops.last_emit_route() == route

    auto = build_plan(MatchSpec(algo="sbm", backend="pallas",
                                interpret=True), S.n, U.n, S.d)
    assert auto.emit_route() == "resident"    # 2048 regions fit VMEM
    tight = build_plan(MatchSpec(algo="sbm", backend="pallas",
                                 interpret=True, emit_budget=1),
                       S.n, U.n, S.d)
    assert tight.emit_route() == "xla"
    # the knob only exists where the two-pass emit kernel runs
    assert build_plan(MatchSpec(algo="bfm", backend="pallas"),
                      S.n, U.n, S.d).emit_route() is None
    assert build_plan(MatchSpec(algo="sbm"), S.n, U.n,
                      S.d).emit_route() is None


def test_engine_emit_budget_routes_pairs():
    """A plan's emit_budget drives the actual pairs() route.

    The engine's default block (2048) carries a ~288 KiB double-buffer
    window, so streaming only wins the policy from n+m ≈ 2.5e4 up.
    """
    S, U = paper_workload(seed=17, n_total=65_536, alpha=0.05)
    need = ops.emit_route_bytes(S.n, U.n, block=2048)  # engine block
    assert need["streaming"] < need["resident"]
    spec = MatchSpec(algo="sbm", backend="pallas", capacity="fixed",
                     max_pairs=256, interpret=True,
                     emit_budget=need["resident"] - 1)
    plan = build_plan(spec, S.n, U.n, S.d)
    assert plan.emit_route() == "streaming"
    pairs, k = plan.pairs(S, U)
    assert ops.last_emit_route() == "streaming"
    want_p, want_c = sbm_pairs(S, U, 256)
    assert k == want_c
    np.testing.assert_array_equal(np.asarray(pairs), np.asarray(want_p))


# ---------------------------------------------------------------------------
# route-policy properties (satellite of the static auditor: the same
# byte model the kernel parity audit pins is checked as a function here)
# ---------------------------------------------------------------------------

def _policy_sizes():
    """(n, m) ladder spanning 1e2..4e6 total, asymmetric splits too."""
    sizes = []
    for e in (128, 1000, 4096, 30_000, 250_000, 1_000_000, 4_000_000):
        sizes.append((e // 2, e - e // 2))
        sizes.append((e // 4, e - e // 4))
    return sizes


def test_emit_route_bytes_monotone_in_problem_size():
    """Per route, modeled bytes never decrease as n+m grows — the
    policy's budget comparison is only sound against a monotone model."""
    for block in (DEF_BLOCK, 2048):
        prev = {"resident": -1, "streaming": -1, "csr": -1}
        for n, m in sorted(_policy_sizes(), key=lambda t: t[0] + t[1]):
            need = ops.emit_route_bytes(n, m, block=block)
            for route in ("resident", "streaming", "csr"):
                assert need[route] >= prev[route], \
                    (route, n, m, block, need, prev)
                prev[route] = need[route]


def test_route_flip_exactly_at_budget_boundary_property():
    """At every size: budget == need[route] keeps the route, one byte
    less drops to the next cheaper regime.  Exhaustive over the ladder,
    not just one hand-picked size."""
    for n, m in _policy_sizes():
        need = ops.emit_route_bytes(n, m)
        assert need["streaming"] <= need["resident"] or n + m < 4096
        r_hi = ops.choose_emit_route(n, m, budget=need["resident"])
        assert r_hi == "resident", (n, m)
        lo = ops.choose_emit_route(n, m, budget=need["resident"] - 1)
        assert lo == ("streaming" if need["streaming"]
                      <= need["resident"] - 1 else "xla"), (n, m)
        assert ops.choose_emit_route(n, m, budget=need["streaming"]) \
            in ("resident", "streaming")
        below_dense = min(need["streaming"], need["resident"]) - 1
        assert ops.choose_emit_route(n, m, budget=below_dense) \
            == ("csr" if need["csr"] <= below_dense else "xla"), (n, m)
        # csr is the last kernel route; below its constant need only
        # the XLA fallback remains (and dense-only callers skip it)
        assert ops.choose_emit_route(n, m, budget=need["csr"] - 1) \
            in ("resident", "xla")
        assert ops.choose_emit_route(n, m, budget=below_dense,
                                     dense_only=True) == "xla", (n, m)
        assert ops.choose_emit_route(n, m, budget=0) == "xla"


def test_max_pairs_zero_builds_no_kernel_on_any_route():
    """max_pairs == 0 must short-circuit *before* pallas_call on every
    route — proven by capturing pallas_call invocations, not just by
    output shape."""
    from repro.analysis import capture_pallas_calls

    S, U = paper_workload(seed=37, n_total=256, alpha=1.0)
    for route in ("resident", "streaming", "csr", "xla", "auto"):
        records = []
        with capture_pallas_calls(records):
            pairs, count = ops.twopass_pairs_pallas(
                S, U, 0, interpret=True, route=route)
        assert tuple(pairs.shape) == (0, 2), route
        assert count > 0                     # the true K is still exact
        emit_calls = [r for r in records if "emit" in r.kernel_name]
        assert not emit_calls, (route, [r.kernel_name for r in records])


# ---------------------------------------------------------------------------
# the real thresholds, at real sizes (interpret mode, small K caps)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_total,expect", [
    (500_000, "resident"),    # just under the old ~5.24e5 VMEM ceiling
    (600_000, "streaming"),   # past it: only the streaming kernel fits
    (2_200_000, "csr"),       # past the dense routes: csr decode view
])
def test_default_threshold_straddle_runs_pallas(n_total, expect):
    """Above the old fallback threshold the *streaming kernel* (not the
    XLA fallback) runs, and is bit-identical to the XLA pass 2."""
    S, U = paper_workload(seed=29, n_total=n_total, alpha=0.02)
    assert ops.choose_emit_route(S.n, U.n) == expect
    cap = 2048
    want_p, want_c = sbm_pairs(S, U, cap)
    got_p, got_c = ops.twopass_pairs_pallas(S, U, cap, interpret=True)
    assert ops.last_emit_route() == expect
    assert got_c == want_c
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))


def test_streaming_bitexact_at_2e6():
    """The paper's benchmark regime: n+m = 2e6 streams, bit-identically."""
    S, U = paper_workload(seed=31, n_total=2_000_000, alpha=0.01)
    assert ops.choose_emit_route(S.n, U.n) == "streaming"
    cap = 1024
    want_p, want_c = sbm_pairs(S, U, cap)
    got_p, got_c = ops.twopass_pairs_pallas(S, U, cap, interpret=True)
    assert ops.last_emit_route() == "streaming"
    assert got_c == want_c
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))
