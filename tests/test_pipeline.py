"""GPipe pipeline schedule == serial layer stack (subprocess, 4 fake
devices as stages)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.runtime.pipeline import pipeline_forward, AXIS

    L, B, D = 8, 12, 16
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (L, D, D)) * (D ** -0.5)
    b = jax.random.normal(jax.random.fold_in(key, 1), (L, D)) * 0.1
    params = {"w": w, "b": b}
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, D))

    def layer_apply(p, xin):
        return jnp.tanh(xin @ p["w"] + p["b"])

    # serial reference
    ref = x
    for i in range(L):
        ref = layer_apply({"w": w[i], "b": b[i]}, ref)

    mesh = Mesh(np.array(jax.devices()), (AXIS,))
    for nmb in (2, 3, 6):
        out = pipeline_forward(params, x, layer_apply, mesh=mesh,
                               n_microbatches=nmb)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
    print("PIPE_OK")
""")


def test_pipeline_matches_serial():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-1500:]
    assert "PIPE_OK" in out.stdout
