"""The static auditor itself: passes must flag the corpus, stay clean
on the repo, and the promoted diagnostics must name what went wrong."""
import json
from pathlib import Path

import pytest
import jax
import jax.numpy as jnp

from repro import analysis
from repro.analysis.corpus import run_corpus
from repro.analysis.jaxpr_audit import scale_dims
from repro.analysis.matrix import (audit_kernel_matrix, audit_plan_matrix,
                                   audit_retrace_matrix)
from repro.analysis.report import Report
from repro.core import paper_workload
from repro.core.dd_match import pairs_to_set
from repro.core.engine import MatchPlan, MatchSpec, build_plan
from repro.core.regions import Regions
from repro.kernels import ops

REPO = Path(__file__).resolve().parents[1]
CORPUS = REPO / "tests" / "analysis_corpus"


# ---------------------------------------------------------------------------
# the corpus is the auditor's own regression suite
# ---------------------------------------------------------------------------

def test_corpus_every_seeded_defect_detected():
    results = run_corpus(CORPUS)
    assert results, "corpus is empty"
    missed = [f"{r.module}:{r.name} ({r.error or 'no finding'})"
              for r in results if not r.ok]
    assert not missed, f"auditor missed seeded defects: {missed}"
    # each pass is exercised by at least two seeded defects
    by_pass = {}
    for r in results:
        by_pass.setdefault(r.pass_name, []).append(r)
    for p in ("jaxpr", "kernel", "retrace", "lint"):
        assert len(by_pass.get(p, [])) >= 2, p


# ---------------------------------------------------------------------------
# repo must audit clean (cheap slices of the full matrix)
# ---------------------------------------------------------------------------

def test_kernel_matrix_and_route_parity_clean():
    report = Report()
    audit_kernel_matrix(report)
    assert report.ok(), [str(f) for f in report.errors()]
    parity = report.audited["kernel"]
    assert any("emit_route_parity:resident" in t for t in parity)
    assert any("emit_route_parity:streaming" in t for t in parity)


def test_route_parity_detects_model_drift(monkeypatch):
    drifted = lambda n, m, block=512: {  # noqa: E731
        "resident": 1, "streaming": 1, "csr": 1}
    monkeypatch.setattr(ops, "emit_route_bytes",
                        lambda n, m, *, block=512: drifted(n, m, block))
    report = Report()
    analysis.audit_emit_route_parity(report, n=2000, m=1500,
                                     max_pairs=4096)
    assert {"K_ROUTE_DRIFT"} == report.codes()


def test_retrace_matrix_clean():
    report = Report()
    audit_retrace_matrix(report)
    assert report.ok(), [str(f) for f in report.errors()]


def test_plan_matrix_row_clean_and_scaled():
    report = Report()
    audit_plan_matrix(report, rows=[("sbm", "xla", "grow")])
    assert report.ok(), [str(f) for f in report.errors()]
    assert any("sbm/xla/grow" in t for t in report.audited["jaxpr"])


def test_lint_repo_sources_clean():
    report = Report()
    n = analysis.lint_paths(REPO, report=report)
    assert n > 10  # src/ + benchmarks/ really were scanned
    assert report.ok(), [str(f) for f in report.errors()]


# ---------------------------------------------------------------------------
# no_retrace: the counter promoted to an enforceable guard
# ---------------------------------------------------------------------------

def _small_problem():
    S, U = paper_workload(seed=5, n_total=256, alpha=1.0)
    return S, U


def test_no_retrace_steady_state_passes():
    S, U = _small_problem()
    plan = MatchPlan(MatchSpec(algo="sbm", capacity="grow"), S.n, U.n, 1)
    plan.count(S, U)
    plan.pairs(S, U)
    with analysis.no_retrace(plan):
        plan.count(S, U)
        plan.pairs(S, U)


def test_no_retrace_raises_with_executable_names():
    S, U = _small_problem()
    plan = MatchPlan(MatchSpec(algo="sbm", capacity="grow"), S.n, U.n, 1)
    with pytest.raises(analysis.RetraceError) as ei:
        with analysis.no_retrace(plan):
            plan.count(S, U)
    msg = str(ei.value)
    assert "sbm_contribs" in msg        # names the executable that traced
    assert "MatchPlan" in msg           # and the plan


def test_no_retrace_allow_budget():
    S, U = _small_problem()
    plan = MatchPlan(MatchSpec(algo="sbm", capacity="grow"), S.n, U.n, 1)
    with analysis.no_retrace(plan, allow=8):
        plan.count(S, U)
        plan.pairs(S, U)


def test_grow_bound_engine_within_log_budget():
    from repro.analysis.retrace import engine_grow_resolver_factory
    report = Report()
    analysis.audit_grow_bound(engine_grow_resolver_factory(),
                              max_k=1 << 16, target="engine",
                              report=report)
    assert report.ok()


def test_grow_bound_flags_linear_resolver():
    report = Report()
    analysis.audit_grow_bound(lambda: (lambda k: max(k, 1)),
                              max_k=1 << 16, target="linear",
                              report=report)
    assert "R_GROW_BOUND" in report.codes()


# ---------------------------------------------------------------------------
# promoted diagnostics: index-range failures name the offenders
# ---------------------------------------------------------------------------

def test_pairs_to_set_reports_offending_slots():
    bad = jnp.asarray([[0, 1], [2, 9], [1, -3], [-1, 4], [-1, -1]],
                      jnp.int32)
    with pytest.raises(ValueError) as ei:
        pairs_to_set(bad, m=5, n=3, context="unit-test")
    msg = str(ei.value)
    assert "outside [0, 5)" in msg          # update range
    assert "slot 1" in msg and "u=9" in msg  # names the slot and value
    assert "half-padded" in msg              # the (-1, 4) row
    assert "context='unit-test'" in msg


def test_validate_pairs_names_plan_and_count_mismatch():
    plan = build_plan(MatchSpec(algo="sbm", capacity="fixed",
                                max_pairs=4), 3, 5, 1)
    good = jnp.asarray([[0, 1], [2, 4], [-1, -1], [-1, -1]], jnp.int32)
    plan.validate_pairs(good, count=2)      # no raise
    bad = jnp.asarray([[0, 1], [7, 4], [-1, -1], [-1, -1]], jnp.int32)
    with pytest.raises(ValueError) as ei:
        plan.validate_pairs(bad, count=2)
    msg = str(ei.value)
    assert "subscription index(es) outside [0, 3)" in msg
    assert "MatchPlan(algo=sbm" in msg
    with pytest.raises(ValueError, match="reported count is 3"):
        plan.validate_pairs(good, count=3)


def test_bfm_pairs_refuses_int32_mask_overflow():
    n = 50_000
    lo = jnp.zeros((n, 1), jnp.float32)
    hi = jnp.ones((n, 1), jnp.float32)
    S = U = Regions(lo, hi)
    with pytest.raises(ValueError, match="INT32_MAX"):
        ops.bfm_pairs_pallas(S, U, 8, interpret=True)


# ---------------------------------------------------------------------------
# scaling + report plumbing
# ---------------------------------------------------------------------------

def test_scale_dims_resolves_probe_primes():
    probe = {"n": 37, "m": 29, "cap": 53}
    target = {"n": 1000, "m": 700, "cap": 4096}
    dim_map, unresolved = scale_dims(probe, target)
    assert dim_map(37) == 1000
    assert dim_map(29) == 700
    assert dim_map(66) == 1700        # n+m
    assert dim_map(67) == 1701        # n+m+1
    assert dim_map(37 * 29) == 1000 * 700
    assert dim_map(53) == 4096
    assert dim_map(1) == 1 and dim_map(2) == 2   # small constants pass
    assert not unresolved
    dim_map(97)                       # unknown large dim
    assert 97 in unresolved


def test_report_json_roundtrip(tmp_path):
    report = Report()
    report.add("lint", "L_DEPRECATED", "a.py:3", "msg")
    report.note_audit("lint", "a.py")
    p = tmp_path / "r.json"
    report.write_json(str(p))
    data = json.loads(p.read_text())
    assert data["ok"] is False
    assert data["n_errors"] == 1
    assert data["findings"][0]["code"] == "L_DEPRECATED"
    assert data["audited"]["lint"] == ["a.py"]


def test_capture_hook_restored_after_context():
    from repro.core import engine
    before = engine._JIT_CAPTURE_HOOK
    with analysis.capture_plan_executables([]):
        assert engine._JIT_CAPTURE_HOOK is not None
    assert engine._JIT_CAPTURE_HOOK is before


def test_trace_kernel_captures_specs_without_execution():
    from repro.kernels import emit as emit_kernel
    import functools
    n = m = 500_000                   # far past anything we'd execute
    caps = analysis.trace_kernel(
        functools.partial(emit_kernel.twopass_emit_streaming, n=n, m=m,
                          max_pairs=1 << 20, block=512),
        jax.ShapeDtypeStruct((n + m + 1,), jnp.int32),
        jax.ShapeDtypeStruct((n + m,), jnp.int32),
        jax.ShapeDtypeStruct((n + m,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((m,), jnp.int32))
    assert len(caps) == 1
    cap = caps[0]
    assert cap.num_scalar_prefetch == 1
    assert cap.grid == ((1 << 20) // 512,)
    assert analysis.vmem_footprint(cap) > 0
