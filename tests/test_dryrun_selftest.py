"""Dry-run machinery selftest (subprocess: fakes 16 devices, reduced
configs, both mesh topologies).  The full-size 512-device sweep is run
offline via ``python -m repro.launch.dryrun`` — its results live in
experiments/dryrun/ and are validated by test_dryrun_results.py."""
import json
import os
import subprocess
import sys
from pathlib import Path


def test_dryrun_smoke_cells(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    env["REPRO_DRYRUN_DEVICES"] = "16"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
         "--arch", "mamba2-780m", "--shape", "long_500k",
         "--mesh", "both", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    for mesh in ("single", "multi"):
        rec = json.loads(
            (tmp_path / f"mamba2_780m_long_500k_{mesh}.json").read_text())
        assert rec["status"] == "ok", rec
        assert rec["roofline"]["dominant"] in ("compute_s", "memory_s",
                                               "collective_s")
        assert rec["memory"]["peak_estimate"] > 0
        # multi-pod proves the 'pod' axis lowers
        if mesh == "multi":
            assert rec["n_devices"] == 16


def test_full_sweep_results_if_present():
    """Validate the offline 512-device sweep artifacts (all 40 cells × 2
    meshes): no errors; skips only for documented long_500k cells."""
    d = Path(__file__).parent.parent / "experiments" / "dryrun"
    files = sorted(d.glob("*.json")) if d.exists() else []
    if len(files) < 80:
        import pytest
        pytest.skip(f"full sweep incomplete ({len(files)}/80 cells)")
    errors = []
    skips = 0
    for f in files:
        rec = json.loads(f.read_text())
        if rec["status"] == "error":
            errors.append(f.name)
        elif rec["status"] == "skipped":
            skips += 1
            assert rec["shape"] == "long_500k", rec
        else:
            assert rec["memory"]["peak_estimate"] > 0
            # must fit a v5e chip (16 GB HBM), after correcting for the
            # CPU backend's bf16→f32 legalization copies (absent on TPU;
            # see dryrun.bf16_ghost_bytes).  Known exceptions, each with
            # a diagnosed mechanism + remediation in EXPERIMENTS §Dry-run
            # (all deepseek-v2-236b: fp32-Adam floor / SPMD router
            # gather pathology):
            known_over = {
                ("deepseek_v2_236b", "train_4k", "single"),
                ("deepseek_v2_236b", "train_4k", "multi"),
                ("deepseek_v2_236b", "prefill_32k", "multi"),
                ("phi3_5_moe_42b", "prefill_32k", "multi"),
            }
            peak = rec["memory"].get("peak_tpu_estimate",
                                     rec["memory"]["peak_estimate"])
            key = (rec["arch"], rec["shape"], rec["mesh"])
            if key not in known_over:
                assert peak < 16e9, (f.name, peak)
    assert not errors, errors
    assert skips == 16  # 8 pure-attention archs × 2 meshes
