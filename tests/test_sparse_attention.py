"""DDM block-sparse attention: planner algebra + Pallas kernel vs oracle.

Chain under test (DESIGN.md §3):
  core interval matching → sparse.planner (bitmask / windows)
  → kernels.sparse_attn (interpret) ≙ dense attention under the same mask.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.sparse.planner import (BlockPlan, block_bitmask, block_windows,
                                  decode_window)
from repro.kernels.sparse_attn import sparse_attn_1h, sparse_attn


def dense_masked_attention(q, k, v, allowed):
    scores = (q.astype(np.float64) @ k.astype(np.float64).T
              ) / np.sqrt(q.shape[-1])
    scores = np.where(allowed, scores, -np.inf)
    w = np.exp(scores - scores.max(axis=-1, keepdims=True))
    w = np.where(np.isfinite(scores), w, 0.0)
    denom = w.sum(axis=-1, keepdims=True)
    denom = np.where(denom > 0, denom, 1.0)
    return (w / denom) @ v.astype(np.float64)


def token_mask_from_plan(plan: BlockPlan) -> np.ndarray:
    """(S, S) token-level mask implied by the plan (window+sink+causal)."""
    S = plan.seq_len
    qp = np.arange(S)[:, None]
    kp = np.arange(S)[None, :]
    causal = kp <= qp
    in_window = kp > qp - plan.window
    # block-granular: a q token shares its q-block's window start, which
    # is aligned down to block boundaries
    starts, ends = block_windows(plan)
    qb = np.arange(S) // plan.block_q
    win = (kp >= starts[qb][:, None]) & (kp < ends[qb][:, None])
    sink = kp < plan.sink_end
    return causal & (win | sink)


@pytest.mark.parametrize("seq,window,bq,bkv,sink", [
    (256, 64, 32, 32, 1),
    (512, 128, 64, 32, 2),
    (128, 512, 32, 32, 0),   # window covers everything
])
def test_planner_bitmask_matches_windows(seq, window, bq, bkv, sink):
    plan = BlockPlan(seq, bq, bkv, window, sink)
    bm = block_bitmask(plan)
    starts, ends = block_windows(plan)
    # windows are the contiguous hull of the non-sink bitmask columns
    for i in range(plan.nq):
        cols = np.nonzero(bm[i, sink:])[0] + sink
        if len(cols):
            assert starts[i] <= cols.min() * bkv
            assert ends[i] >= min((cols.max() + 1) * bkv, seq) or \
                ends[i] == min((i + 1) * bq, seq)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("seq,window,bq,bkv,sink", [
    (256, 64, 32, 32, 1),
    (256, 96, 64, 32, 0),
    (128, 1024, 32, 32, 1),
])
def test_sparse_attn_kernel_vs_dense_masked(dtype, seq, window, bq, bkv,
                                            sink):
    plan = BlockPlan(seq, bq, bkv, window, sink)
    starts, ends = block_windows(plan)
    rng = np.random.default_rng(3)
    dh = 64
    q = rng.normal(size=(seq, dh)).astype(np.float32)
    k = rng.normal(size=(seq, dh)).astype(np.float32)
    v = rng.normal(size=(seq, dh)).astype(np.float32)
    got = sparse_attn_1h(jnp.asarray(q, dtype), jnp.asarray(k, dtype),
                         jnp.asarray(v, dtype), jnp.asarray(starts),
                         jnp.asarray(ends), bq=bq, bkv=bkv,
                         sink_end=plan.sink_end, interpret=True)
    allowed = token_mask_from_plan(plan)
    want = dense_masked_attention(q, k, v, allowed)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float64), want,
                               rtol=tol, atol=tol)


def test_sparse_attn_batched_heads():
    plan = BlockPlan(128, 32, 32, 64, 1)
    starts, ends = block_windows(plan)
    rng = np.random.default_rng(5)
    B, H, dh = 2, 3, 32
    q = jnp.asarray(rng.normal(size=(B, 128, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, 128, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, 128, H, dh)), jnp.float32)
    out = sparse_attn(q, k, v, jnp.asarray(starts), jnp.asarray(ends),
                      bq=32, bkv=32, sink_end=plan.sink_end,
                      interpret=True)
    assert out.shape == (B, 128, H, dh)
    allowed = token_mask_from_plan(plan)
    for b in range(B):
        for h in range(H):
            want = dense_masked_attention(np.asarray(q)[b, :, h],
                                          np.asarray(k)[b, :, h],
                                          np.asarray(v)[b, :, h], allowed)
            np.testing.assert_allclose(
                np.asarray(out)[b, :, h].astype(np.float64), want,
                rtol=2e-5, atol=2e-5)


def test_decode_window_matches_attention_mask_semantics():
    """decode_window == the window/sink predicate in models.attention."""
    plan = BlockPlan(4096, 128, 128, 512, 1)
    for pos in (0, 100, 511, 512, 4000):
        start, end = decode_window(pos, plan)
        kv = np.arange(4096)
        # attention.py predicate: (kv > pos - window) | (kv < sink_end)
        pred = ((kv > pos - plan.window) | (kv < plan.sink_end)) \
            & (kv <= pos)
        plan_read = ((kv >= start) & (kv < end)) | (kv < plan.sink_end)
        plan_read &= kv <= pos
        np.testing.assert_array_equal(pred, plan_read)
