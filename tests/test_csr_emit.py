"""CSR emit route: decode edge cases, lazy-view contract, capacity
policies over the compressed offset arrays, and parity-as-sets against
the distributed backend.

The dense-route parity matrix lives in test_emit_routing.py; this file
exercises what is *new* about the csr route — the decode kernel's
window semantics (any start offset, any size, −1 pads past the true
count), the degenerate table shapes (K = 0, one emitter, all-overlap
quadratic K), and the CSRPairs view's contract (windows(), __array__,
compressed footprint, pairs_to_set streaming consumption).
"""
import numpy as np
import pytest

from repro.core import MatchSpec, build_plan, make_regions, paper_workload
from repro.core.dd_match import pairs_to_set
from repro.core.sbm import sbm_pairs
from repro.kernels import ops

from proputils import interval_cases


def _csr(S, U, cap, **kw):
    view, k = ops.twopass_pairs_csr(S, U, cap, interpret=True, **kw)
    assert isinstance(view, ops.CSRPairs)
    return view, k


# ---------------------------------------------------------------------------
# decode edge cases
# ---------------------------------------------------------------------------

def test_k_zero_decodes_all_pad():
    """Non-empty sets, zero overlaps: every slot decodes to the pad."""
    S = make_regions(np.zeros((16, 1)), np.full((16, 1), 0.5))
    U = make_regions(np.full((8, 1), 100.0), np.full((8, 1), 101.0))
    view, k = _csr(S, U, 512)
    assert k == 0 and view.count == 0
    assert (np.asarray(view) == -1).all()
    assert view.shape == (512, 2)
    assert pairs_to_set(view, U.n, S.n) == set()


def test_single_emitter_run():
    """One subscription overlapping many updates: one CSR run covers
    the whole buffer, crossing several decode tiles."""
    S = make_regions(np.zeros((1, 1)), np.ones((1, 1)))
    u = np.linspace(0.1, 0.9, 700, dtype=np.float32)[:, None]
    U = make_regions(u, u + 1e-3)
    want_p, want_c = sbm_pairs(S, U, 1024)
    view, k = _csr(S, U, 1024)
    assert k == want_c == 700
    np.testing.assert_array_equal(np.asarray(view), np.asarray(want_p))


def test_all_overlap_quadratic_k():
    """All-overlap workload: K = n*m, the regime the CSR form exists
    for — compressed bytes stay O(n+m) while the dense buffer is O(K)."""
    n, m = 96, 80
    S = make_regions(np.zeros((n, 1)), np.ones((n, 1)))
    U = make_regions(np.zeros((m, 1)), np.ones((m, 1)))
    cap = n * m
    want_p, want_c = sbm_pairs(S, U, cap)
    view, k = _csr(S, U, cap)
    assert k == want_c == n * m
    np.testing.assert_array_equal(np.asarray(view), np.asarray(want_p))
    assert pairs_to_set(view, m, n) == {s * m + u for s in range(n)
                                       for u in range(m)}
    assert view.nbytes < view.dense_nbytes


def test_decode_window_slicing_parity():
    """decode(a, b) == dense[a:b] for arbitrary (unaligned) windows,
    across randomized workloads — the lazy view's core contract."""
    for seed, s_lo, s_hi, u_lo, u_hi in interval_cases(n_cases=6, d=1):
        S = make_regions(s_lo, s_hi)
        U = make_regions(u_lo, u_hi)
        _, k = sbm_pairs(S, U, 1)
        cap = max(k + 130, 2 * k, 256)      # pad tail crosses tiles
        want = np.asarray(sbm_pairs(S, U, cap)[0])
        view, got_k = _csr(S, U, cap)
        assert got_k == k, seed
        windows = [(0, cap), (0, 1), (cap - 1, cap), (3, 131),
                   (127, 129), (cap // 3, min(cap // 3 + 257, cap))]
        for a, b in windows:
            np.testing.assert_array_equal(
                np.asarray(view.decode(a, b)), want[a:b],
                err_msg=f"seed={seed} window=[{a},{b})")
        # windows() reassembles the dense buffer exactly
        chunks = list(view.windows(chunk=97))
        assert chunks[0][0] == 0 and sum(c.shape[0] for _, c in chunks) \
            == cap
        np.testing.assert_array_equal(np.concatenate([c for _, c in
                                                      chunks]), want)


def test_decode_window_validation():
    S, U = paper_workload(seed=5, n_total=64, alpha=1.0)
    view, _ = _csr(S, U, 128)
    with pytest.raises(ValueError, match="outside"):
        view.decode(-1, 4)
    with pytest.raises(ValueError, match="outside"):
        view.decode(0, 129)
    with pytest.raises(ValueError, match="outside"):
        view.decode(10, 9)
    assert view.decode(7, 7).shape == (0, 2)


def test_truncation_pads_beyond_cap_are_trimmed():
    """cap < K: the view reports the true K and its decoded buffer is
    the same truncated prefix the dense routes emit."""
    S, U = paper_workload(seed=7, n_total=400, alpha=2.0)
    want_p, want_c = sbm_pairs(S, U, 100)
    view, k = _csr(S, U, 100)
    assert k == want_c > 100
    np.testing.assert_array_equal(np.asarray(view), np.asarray(want_p))
    assert len(view) == 100


# ---------------------------------------------------------------------------
# engine capacity policies over the compressed offset arrays
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("capacity", ["exact", "grow", "fixed"])
def test_capacity_policies_on_csr_route(capacity):
    S, U = paper_workload(seed=11, n_total=600, alpha=1.5)
    want_p, want_c = sbm_pairs(S, U, 1 << 14)
    kw = {"max_pairs": 8 if capacity == "grow" else
          (1 << 14 if capacity == "fixed" else None)}
    spec = MatchSpec(algo="sbm", backend="pallas", capacity=capacity,
                     emit_route="csr", interpret=True, **kw)
    plan = build_plan(spec, S.n, U.n, 1, key=("csr-cap", capacity))
    pairs, k = plan.pairs(S, U)
    assert k == want_c
    assert ops.last_emit_route() == "csr"
    assert isinstance(pairs, ops.CSRPairs)
    if capacity == "grow":
        # pow2 doubling resolved over the saturated offset arrays: the
        # re-emit re-packs the tables at the doubled cap, no dense
        # buffer in between
        assert pairs.cap >= k and (pairs.cap & (pairs.cap - 1)) == 0
    plan.validate_pairs(pairs, count=min(k, pairs.cap))
    assert pairs_to_set(pairs, U.n, S.n) \
        == pairs_to_set(np.asarray(want_p)[:pairs.cap], U.n, S.n)


def test_grow_reemit_is_single_doubling():
    """grow with a tiny floor re-emits exactly once (exact K known),
    and both emits stay on the csr route."""
    S, U = paper_workload(seed=13, n_total=512, alpha=1.0)
    spec = MatchSpec(algo="sbm", backend="pallas", capacity="grow",
                     max_pairs=4, emit_route="csr", interpret=True)
    plan = build_plan(spec, S.n, U.n, 1, key=("csr-grow",))
    pairs, k = plan.pairs(S, U)
    assert k > 4 and pairs.cap >= k
    assert ops.last_emit_route() == "csr"
    # steady state: the memoized capacity serves without re-emitting
    pairs2, k2 = plan.pairs(S, U)
    assert k2 == k and pairs2.cap == pairs.cap


# ---------------------------------------------------------------------------
# parity-as-sets vs the distributed backend (the tentpole's cross-
# backend acceptance: csr view == sharded emit == xla, as sets)
# ---------------------------------------------------------------------------

def test_csr_parity_as_sets_vs_distributed():
    for seed, s_lo, s_hi, u_lo, u_hi in interval_cases(n_cases=4, d=1):
        S = make_regions(s_lo, s_hi)
        U = make_regions(u_lo, u_hi)
        csr_plan = build_plan(
            MatchSpec(algo="sbm", backend="pallas", emit_route="csr",
                      capacity="exact", interpret=True),
            S.n, U.n, 1, key=("csr-dist", "csr"))
        dist_plan = build_plan(
            MatchSpec(algo="sbm", backend="distributed",
                      capacity="exact"),
            S.n, U.n, 1, key=("csr-dist", "dist"))
        vp, vk = csr_plan.pairs(S, U)
        dp, dk = dist_plan.pairs(S, U)
        assert vk == dk, seed
        assert pairs_to_set(vp, U.n, S.n) == pairs_to_set(dp, U.n, S.n), \
            seed


# ---------------------------------------------------------------------------
# view/accounting contract
# ---------------------------------------------------------------------------

def test_view_footprint_is_compressed():
    """The device bytes a CSRPairs pins scale with n+m, not with cap —
    the memory claim behind lifting the emit bound."""
    S, U = paper_workload(seed=17, n_total=2048, alpha=1.0)
    small, _ = _csr(S, U, 1 << 10)
    huge, _ = _csr(S, U, 1 << 22)
    assert huge.nbytes == small.nbytes          # cap-independent
    assert huge.dense_nbytes == (1 << 22) * 8
    assert huge.nbytes < huge.dense_nbytes


def test_pairs_to_set_windows_validation_names_window():
    """The windowed pairs_to_set path still validates index ranges and
    names the offending decode window (the unified PairsResult wording,
    shared by every lazy view, CSR included)."""
    S, U = paper_workload(seed=19, n_total=128, alpha=1.0)
    view, k = _csr(S, U, 256)
    assert k > 0
    # lie about the update-set size: every real pair is now out of range
    with pytest.raises(ValueError, match=r"window at slot \d+"):
        pairs_to_set(view, 1, S.n)
