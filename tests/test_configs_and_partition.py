"""Assigned-config fidelity (exact values from the assignment table) +
partitioning rule unit tests."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ALIASES, ARCHS, SHAPES, get_config
from repro.launch import partition as pt

EXPECT = {
    "qwen2-0.5b": dict(n_layers=24, d_model=896, n_heads=14,
                       n_kv_heads=2, d_ff=4864, vocab=151936,
                       qkv_bias=True),
    "llama3.2-3b": dict(n_layers=28, d_model=3072, n_heads=24,
                        n_kv_heads=8, d_ff=8192, vocab=128256),
    "yi-9b": dict(n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
                  d_ff=11008, vocab=64000),
    "qwen3-14b": dict(n_layers=40, d_model=5120, n_heads=40,
                      n_kv_heads=8, d_ff=17408, vocab=151936,
                      qk_norm=True),
    "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32,
                        n_kv_heads=32, d_ff=10240, vocab=32000,
                        ssm_state=64),
    "deepseek-v2-236b": dict(n_layers=60, d_model=5120, n_heads=128,
                             vocab=102400, n_experts=160, top_k=6,
                             n_shared_experts=2, moe_d_ff=1536,
                             kv_lora=512),
    "phi3.5-moe-42b-a6.6b": dict(n_layers=32, d_model=4096, n_heads=32,
                                 n_kv_heads=8, vocab=32064,
                                 n_experts=16, top_k=2, moe_d_ff=6400),
    "chameleon-34b": dict(n_layers=48, d_model=8192, n_heads=64,
                          n_kv_heads=8, d_ff=22016, vocab=65536),
    "mamba2-780m": dict(n_layers=48, d_model=1536, vocab=50280,
                        ssm_state=128),
    "whisper-medium": dict(n_layers=24, d_model=1024, n_heads=16,
                           n_kv_heads=16, d_ff=4096, vocab=51865,
                           enc_layers=24),
}


@pytest.mark.parametrize("name", sorted(EXPECT))
def test_config_matches_assignment(name):
    cfg = get_config(name)
    for k, v in EXPECT[name].items():
        assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)


def test_all_archs_have_param_scale():
    """n_params() lands in the right ballpark per the arch name."""
    approx = {"qwen2-0.5b": 0.5e9, "llama3.2-3b": 3.2e9, "yi-9b": 8.8e9,
              "qwen3-14b": 14e9, "zamba2-2.7b": 2.7e9,
              "deepseek-v2-236b": 236e9, "phi3.5-moe-42b-a6.6b": 42e9,
              "chameleon-34b": 34e9, "mamba2-780m": 0.78e9,
              "whisper-medium": 0.76e9}
    for name, want in approx.items():
        got = get_config(name).n_params()
        assert 0.5 * want < got < 1.7 * want, (name, got, want)


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524_288
    assert SHAPES["long_500k"].global_batch == 1


def test_sanitize_drops_nondivisible_axes():
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1),
                ("data", "model"))
    # fake a 16-way model axis via a mesh-shaped dict
    import types
    m = types.SimpleNamespace(axis_names=("data", "model"),
                              devices=np.empty((16, 16)))
    spec = pt.sanitize(m, P("data", "model"), (32, 30))
    assert spec == P("data", None)          # 30 % 16 != 0
    spec = pt.sanitize(m, P(("data", "model"),), (256,))
    assert spec == P(("data", "model"))
    spec = pt.sanitize(m, P(("data", "model"),), (100,))
    assert spec == P(None)


def test_param_specs_rules():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("llama3_2_3b")
    params = jax.eval_shape(lambda k: T.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = pt.param_specs(params)
    flat = dict(
        ("/".join(str(getattr(e, "key", e)) for e in path), s)
        for path, s in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0])
    assert flat["embed/table"] == P("model", "data")
    assert flat["layers/attn/wq/w"] == P(None, "data", "model")
    assert flat["layers/attn/wo/w"] == P(None, "model", "data")
    assert flat["layers/mlp/w_down/w"] == P(None, "model", "data")
    assert flat["lm_head/w"] == P("data", "model")
    # norm scales replicate
    assert flat["layers/ln1/scale"] == P()
