"""Execute every fenced Python block in README.md and docs/*.md.

Blocks run in file order sharing one namespace per file (a later block
may build on an earlier one, exactly as a reader would run them), so
each documented example is an executable contract: if the API drifts,
CI fails here naming the file and block.  Non-Python fences (```bash,
bare ```) are shell transcripts and are not executed.
"""
import gc
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = sorted([REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))],
                   key=lambda p: str(p.relative_to(REPO)))

_FENCE = re.compile(r"^```python[ \t]*\n(.*?)^```[ \t]*$",
                    re.DOTALL | re.MULTILINE)


def _blocks(path: Path) -> list[str]:
    return [m.group(1) for m in _FENCE.finditer(path.read_text())]


def test_every_doc_file_is_covered():
    """The parametrization below must see every markdown doc."""
    assert (REPO / "README.md") in DOC_FILES
    assert any(p.name == "API.md" for p in DOC_FILES)
    assert any(p.name == "ARCHITECTURE.md" for p in DOC_FILES)


@pytest.mark.parametrize(
    "doc", DOC_FILES, ids=[str(p.relative_to(REPO)) for p in DOC_FILES])
def test_fenced_python_blocks_execute(doc):
    blocks = _blocks(doc)
    ns: dict = {}
    try:
        for i, src in enumerate(blocks):
            try:
                exec(compile(src, f"{doc.name}[python block {i + 1}]",
                             "exec"), ns)
            except Exception as e:  # noqa: BLE001 — re-raise with location
                raise AssertionError(
                    f"{doc.relative_to(REPO)}: python block {i + 1} of "
                    f"{len(blocks)} failed: {type(e).__name__}: {e}\n"
                    f"--- block source ---\n{src}") from e
    finally:
        # the namespaces hold jitted callables; drop them and collect
        # *before* the per-module jax.clear_caches() teardown iterates
        # its weakref set, or dying weakrefs mutate it mid-iteration
        ns.clear()
        gc.collect()
