"""Distributed pair enumeration + multi-device DDMService queries.

The in-process tests run on whatever mesh the process sees — one device
under plain pytest, a real 8-device host mesh in the CI
``distributed-smoke`` job (``XLA_FLAGS=--xla_force_host_platform_
device_count=8``).  The subprocess test always forces the 8-device mesh
(the acceptance criterion), so tier-1 on a single-device host still
covers multi-device parity; per launch policy only explicitly
distributed entry points fake the device count in-process.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import (DDMService, MatchSpec, brute, build_plan,
                        distributed, itm, make_regions, paper_workload,
                        pairs_to_set)
from repro.core.engine import MatchPlan

# alpha per d giving a non-trivial K on the small workloads below
ALPHA = {1: 5.0, 2: 20.0, 3: 60.0}


def _dist(algo="sbm", **kw):
    return MatchSpec(algo=algo, backend="distributed", **kw)


def _row_sets(ids):
    ids = np.asarray(ids)
    return [set(int(x) for x in r if x >= 0) for r in ids]


# ---------------------------------------------------------------------------
# pairs(): parity-as-sets vs xla, d ∈ {1, 2, 3}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", (1, 2, 3))
def test_distributed_pairs_set_parity(d):
    for seed in (0, 1):
        S, U = paper_workload(seed=seed, n_total=400, alpha=ALPHA[d], d=d)
        ref = build_plan(MatchSpec(algo="sbm"), S.n, U.n, d)
        rp, rk = ref.pairs(S, U)
        want = pairs_to_set(rp, U.n, S.n)
        plan = MatchPlan(_dist(), S.n, U.n, d)
        assert plan.count(S, U) == rk, (seed, d)
        pairs, k = plan.pairs(S, U)
        assert k == rk, (seed, d)
        assert pairs_to_set(pairs, U.n, S.n) == want, (seed, d)


def test_distributed_capacity_policies():
    S, U = paper_workload(seed=3, n_total=300, alpha=ALPHA[2], d=2)
    exact = MatchPlan(_dist(capacity="exact"), S.n, U.n, 2)
    grow = MatchPlan(_dist(capacity="grow", max_pairs=4), S.n, U.n, 2)
    pe, ke = exact.pairs(S, U)
    pg, kg = grow.pairs(S, U)
    assert ke == kg > 4
    assert pe.shape[0] == ke                  # exact: buffer is exactly K
    assert pg.shape[0] >= ke
    assert pairs_to_set(pe, U.n, S.n) == pairs_to_set(pg, U.n, S.n)
    # fixed truncates the buffer but still reports the exact K
    fixed = MatchPlan(_dist(capacity="fixed", max_pairs=3), S.n, U.n, 2)
    pf, kf = fixed.pairs(S, U)
    assert kf == ke and pf.shape == (3, 2)
    assert pairs_to_set(pf, U.n, S.n) <= pairs_to_set(pe, U.n, S.n)


def test_distributed_pairs_zero_retrace_on_repeat():
    S, U = paper_workload(seed=5, n_total=240, alpha=ALPHA[2], d=2)
    plan = MatchPlan(_dist(capacity="grow"), S.n, U.n, 2)
    p1, k1 = plan.pairs(S, U)
    warm = plan.traces
    for _ in range(3):
        p2, k2 = plan.pairs(S, U)
    assert plan.traces == warm
    assert k2 == k1
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_distributed_empty_sets():
    empty = make_regions(np.zeros((0, 1)), np.zeros((0, 1)))
    one = make_regions(np.array([[1.0]]), np.array([[4.0]]))
    for S, U, want in ((empty, one, 0), (one, empty, 0),
                       (empty, empty, 0), (one, one, 1)):
        plan = MatchPlan(_dist(capacity="grow"), S.n, U.n, 1)
        assert plan.count(S, U) == want
        pairs, k = plan.pairs(S, U)
        assert k == want
        assert len(pairs_to_set(pairs, max(U.n, 1), max(S.n, 1))) == want


def test_distributed_duplicate_endpoints():
    # five identical intervals each side: all 25 pairs; plus adjacent
    # half-open intervals [a,b) / [b,c) that must NOT match
    s_lo = np.array([[10.0]] * 5 + [[0.0]])
    s_hi = np.array([[20.0]] * 5 + [[10.0]])
    u_lo = np.array([[10.0]] * 5 + [[20.0]])
    u_hi = np.array([[20.0]] * 5 + [[30.0]])
    S, U = make_regions(s_lo, s_hi), make_regions(u_lo, u_hi)
    ref = build_plan(MatchSpec(algo="sbm"), S.n, U.n, 1)
    rp, rk = ref.pairs(S, U)
    assert rk == 25
    plan = MatchPlan(_dist(), S.n, U.n, 1)
    pairs, k = plan.pairs(S, U)
    assert k == 25
    assert pairs_to_set(pairs, U.n, S.n) == pairs_to_set(rp, U.n, S.n)


def test_distributed_rejects_non_sbm_and_mask():
    S, U = paper_workload(seed=1, n_total=100, alpha=2.0)
    plan = MatchPlan(_dist(algo="bfm"), S.n, U.n, 1)
    with pytest.raises(ValueError):
        plan.count(S, U)
    with pytest.raises(NotImplementedError):
        MatchPlan(_dist(), S.n, U.n, 1).mask(S, U)


# ---------------------------------------------------------------------------
# mesh-size sweep: parity at every P, per-device emit work shrinking
# ---------------------------------------------------------------------------

def _submesh(p):
    if p > len(jax.devices()):
        pytest.skip(f"needs {p} devices, have {len(jax.devices())}")
    return Mesh(np.array(jax.devices()[:p]), ("shards",))


@pytest.mark.parametrize("p", (1, 2, 4, 8))
def test_distributed_mesh_sweep_parity(p):
    mesh = _submesh(p)
    S, U = paper_workload(seed=11, n_total=400, alpha=5.0, d=1)
    ref = build_plan(MatchSpec(algo="sbm"), S.n, U.n, 1)
    rp, rk = ref.pairs(S, U)
    want = pairs_to_set(rp, U.n, S.n)
    plan = MatchPlan(_dist(mesh=mesh), S.n, U.n, 1)
    assert plan.count(S, U) == rk, p
    pairs, k = plan.pairs(S, U)
    assert k == rk, p
    assert pairs_to_set(pairs, U.n, S.n) == want, p


def _emit_cap_dev(S, U, mesh) -> int:
    """Static per-device emit capacity, via the auditor's jit hook."""
    from repro.analysis.capture import capture_plan_executables
    records = []
    with capture_plan_executables(records):
        plan = MatchPlan(_dist(capacity="exact", mesh=mesh), S.n, U.n, 1)
        plan.pairs(S, U)
    caps = [r.kwargs["cap_dev"] for r in records
            if r.name == "dist_pairs_emit"]
    assert caps, "dist_pairs_emit never ran"
    return max(caps)


def test_distributed_emit_work_shrinks_with_mesh():
    # the emit is slot-bound: each device's static work bound is its
    # own share of K (max per-device pass-1 total under ``exact``),
    # not the global buffer — so the captured ``cap_dev`` must shrink
    # as the mesh grows.  A full-cap scan would be flat in P.
    ndev = len(jax.devices())
    if ndev < 2:
        pytest.skip("needs >= 2 devices to compare emit bounds")
    S, U = paper_workload(seed=12, n_total=400, alpha=8.0, d=1)
    c1 = _emit_cap_dev(S, U, _submesh(1))
    cp = _emit_cap_dev(S, U, _submesh(ndev))
    assert cp < c1, (cp, c1)


# ---------------------------------------------------------------------------
# regression: int32 shard partials, prefix splitters, integer queries
# ---------------------------------------------------------------------------

def test_distributed_count_high_k_exceeds_int32():
    # all-overlap: K = n·m = 2,209,000,000 > 2³¹.  A whole-shard int32
    # partial wraps negative (device-side jnp.int64 silently demotes
    # without x64); the block-sum + host-int64 reduction is exact.
    n = m = 47000
    S = make_regions(np.zeros((n, 1)), np.full((n, 1), 10.0))
    U = make_regions(np.full((m, 1), 1.0), np.full((m, 1), 2.0))
    plan = MatchPlan(_dist(), n, m, 1)
    assert plan.count(S, U) == n * m


def test_sample_splitters_span_the_whole_stream():
    # host-ordered stream: a long low-valued prefix (the subscription
    # lows come first) followed by a far high-valued cluster.  A prefix
    # "sample" sees only the low cluster, collapses every splitter
    # below 1.0, and funnels the entire high cluster into one bucket;
    # the strided sample must reach both.
    tot = 200_000
    v = np.concatenate([
        np.linspace(0.0, 1.0, tot // 2),
        np.linspace(1000.0, 1001.0, tot // 2)]).astype(np.float32)
    qs = np.asarray(distributed.sample_splitters(v, tot, 8))
    assert qs.shape == (7,)
    assert qs.max() >= 1000.0          # reached the far cluster
    assert qs.min() <= 1.0             # still covers the prefix
    assert np.all(np.diff(qs) >= 0)
    assert np.asarray(
        distributed.sample_splitters(v, tot, 1)).shape == (0,)


def test_distributed_count_clustered_stream_no_overflow():
    # every S endpoint sits far below every U endpoint, so the stream
    # prefix is entirely S-valued: prefix-drawn splitters collapse into
    # the S range and one bucket receives all 2m U endpoints — a
    # guaranteed OverflowError at overprovision=2.5 on any multi-shard
    # mesh before the strided-sample fix (the 8-device subprocess
    # below exercises exactly this on single-device hosts too).
    n = m = 40000
    s_lo = np.linspace(0.0, 1.0, n)[:, None]
    u_lo = np.linspace(1000.0, 1001.0, m)[:, None]
    S = make_regions(s_lo, s_lo + 0.5)
    U = make_regions(u_lo, u_lo + 0.5)
    assert MatchPlan(_dist(), n, m, 1).count(S, U) == 0


def test_distributed_query_rejects_integer_dtype():
    S, U = paper_workload(seed=13, n_total=120, alpha=4.0, d=2)
    plan = MatchPlan(_dist(algo="itm", capacity="grow"), S.n, U.n, 2)
    tree = itm.build_tree(U)
    q_lo = np.asarray(S.lo[:5]).astype(np.int32)
    q_hi = np.asarray(S.hi[:5]).astype(np.int32) + 1
    with pytest.raises(TypeError, match="floating"):
        plan.query(tree, U, q_lo, q_hi)


# ---------------------------------------------------------------------------
# query(): sharded batched dynamic-service path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", (1, 2, 3))
def test_distributed_query_parity_and_zero_retrace(d):
    S, U = paper_workload(seed=7, n_total=240, alpha=ALPHA[d], d=d)
    tree = itm.build_tree(U)
    local = MatchPlan(MatchSpec(algo="itm", capacity="grow", max_pairs=8),
                      S.n, U.n, d)
    dist = MatchPlan(_dist(algo="itm", capacity="grow", max_pairs=8),
                     S.n, U.n, d)
    li, lc = local.query(tree, U, S.lo, S.hi)
    di, dc = dist.query(tree, U, S.lo, S.hi)
    np.testing.assert_array_equal(np.asarray(lc), np.asarray(dc))
    assert _row_sets(li) == _row_sets(di)
    warm = dist.traces
    for _ in range(3):
        dist.query(tree, U, S.lo, S.hi)
    assert dist.traces == warm, (d, dist.traces, warm)


def test_distributed_query_empty_batch_and_empty_opp():
    S, U = paper_workload(seed=8, n_total=120, alpha=4.0, d=2)
    plan = MatchPlan(_dist(algo="itm", capacity="grow"), S.n, U.n, 2)
    tree = itm.build_tree(U)
    ids, cnt = plan.query(tree, U, S.lo[:0], S.hi[:0])
    assert ids.shape[0] == 0 and cnt.shape[0] == 0
    empty = make_regions(np.zeros((0, 2)), np.zeros((0, 2)))
    tree0 = itm.build_tree(make_regions(np.zeros((1, 2)),
                                        np.ones((1, 2))))
    ids, cnt = plan.query(tree0, empty, S.lo[:4], S.hi[:4])
    assert int(np.sum(np.asarray(cnt))) == 0


def test_ddmservice_distributed_backend_matches_truth():
    S, U = paper_workload(seed=9, n_total=200, alpha=5.0, d=2)
    svc = DDMService(S, U, spec=_dist(algo="itm", capacity="grow",
                                      max_pairs=8))
    svc.connect()
    rng = np.random.default_rng(3)
    for kind in ("sub", "upd", "sub"):
        idx = rng.choice(40, size=9, replace=False)
        lo = rng.uniform(0, 9e5, (9, 2)).astype(np.float32)
        hi = lo + rng.uniform(1.0, 5e4, (9, 2)).astype(np.float32)
        svc.update_regions(kind, idx, lo, hi)
    mask = np.asarray(brute.bfm_mask(
        make_regions(svc.s_lo, svc.s_hi), make_regions(svc.u_lo, svc.u_hi)))
    truth = {(int(a), int(b)) for a, b in zip(*np.nonzero(mask))}
    assert svc.pairs == truth
    assert svc.plan.traces > 0


# ---------------------------------------------------------------------------
# the acceptance criterion: set-identical to xla on an 8-host-device mesh
# ---------------------------------------------------------------------------

DIST8_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    assert len(jax.devices()) == 8, jax.devices()
    from repro.core import (MatchSpec, build_plan, itm, paper_workload,
                            pairs_to_set)
    from repro.core.engine import MatchPlan
    ALPHA = {1: 5.0, 2: 20.0, 3: 60.0}
    for d in (1, 2, 3):
        S, U = paper_workload(seed=d, n_total=600, alpha=ALPHA[d], d=d)
        ref = build_plan(MatchSpec(algo="sbm"), S.n, U.n, d)
        rp, rk = ref.pairs(S, U)
        want = pairs_to_set(rp, U.n, S.n)
        plan = MatchPlan(MatchSpec(algo="sbm", backend="distributed"),
                         S.n, U.n, d)
        assert plan.count(S, U) == rk, d
        pairs, k = plan.pairs(S, U)
        assert k == rk and pairs_to_set(pairs, U.n, S.n) == want, d
        tree = itm.build_tree(U)
        lp = MatchPlan(MatchSpec(algo="itm", capacity="grow",
                                 max_pairs=8), S.n, U.n, d)
        dp = MatchPlan(MatchSpec(algo="itm", backend="distributed",
                                 capacity="grow", max_pairs=8),
                       S.n, U.n, d)
        li, lc = lp.query(tree, U, S.lo, S.hi)
        di, dc = dp.query(tree, U, S.lo, S.hi)
        assert np.array_equal(np.asarray(lc), np.asarray(dc)), d
        li, di = np.asarray(li), np.asarray(di)
        for r in range(S.n):
            assert (set(x for x in li[r] if x >= 0)
                    == set(x for x in di[r] if x >= 0)), (d, r)
        warm = dp.traces
        dp.query(tree, U, S.lo, S.hi)
        assert dp.traces == warm, d
    # mesh-size sweep P in {1, 2, 4, 8}: set parity at every P, and the
    # captured static per-device emit bound (cap_dev) must shrink with
    # the mesh — the slot-bound emit is O(K/P + P) per device, never a
    # full-capacity scan.
    from jax.sharding import Mesh
    from repro.analysis.capture import capture_plan_executables
    S, U = paper_workload(seed=21, n_total=800, alpha=8.0, d=1)
    ref = build_plan(MatchSpec(algo="sbm"), S.n, U.n, 1)
    rp, rk = ref.pairs(S, U)
    want = pairs_to_set(rp, U.n, S.n)
    emit_caps = {}
    for p in (1, 2, 4, 8):
        mesh = Mesh(np.array(jax.devices()[:p]), ("shards",))
        records = []
        with capture_plan_executables(records):
            plan = MatchPlan(
                MatchSpec(algo="sbm", backend="distributed",
                          capacity="exact", mesh=mesh), S.n, U.n, 1)
            pairs, k = plan.pairs(S, U)
        assert k == rk and pairs_to_set(pairs, U.n, S.n) == want, p
        emit_caps[p] = max(r.kwargs["cap_dev"] for r in records
                           if r.name == "dist_pairs_emit")
    assert emit_caps[8] < emit_caps[4] < emit_caps[2] < emit_caps[1], \\
        emit_caps
    # sorted/clustered stream on the real 8-shard mesh: prefix-drawn
    # splitters overflowed here at overprovision=2.5 before the
    # strided-sample fix
    from repro.core import make_regions
    n = m = 40000
    s_lo = np.linspace(0.0, 1.0, n)[:, None]
    u_lo = np.linspace(1000.0, 1001.0, m)[:, None]
    Sc = make_regions(s_lo, s_lo + 0.5)
    Uc = make_regions(u_lo, u_lo + 0.5)
    assert MatchPlan(MatchSpec(algo="sbm", backend="distributed"),
                     n, m, 1).count(Sc, Uc) == 0
    print("DIST8_OK")
""")


def test_distributed_pairs_query_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", DIST8_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "DIST8_OK" in out.stdout
