"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per the kernel contract: sweep shapes and dtypes, assert exact agreement
(integer/boolean outputs — no tolerance needed; the attention kernel in
test_sparse_attention.py uses allclose).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import MatchSpec, build_plan, paper_workload, make_regions
from repro.kernels import ref
from repro.kernels import bfm as bfm_k
from repro.kernels import sbm_sweep as sweep_k
from repro.kernels.ops import (bfm_count_pallas, bfm_mask_pallas,
                               bfm_pairs_pallas, sbm_count_pallas,
                               twopass_pairs_pallas)
from repro.core.sbm import _endpoint_stream, sbm_pairs

from proputils import interval_cases, oracle_mask


@pytest.mark.parametrize("ts,tu", [(8, 128), (16, 16), (128, 256)])
@pytest.mark.parametrize("d", [1, 2, 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bfm_tile_counts_vs_ref(ts, tu, d, dtype):
    rng = np.random.default_rng(ts * 1000 + tu + d)
    n, m = ts * 3, tu * 2
    s_lo = rng.uniform(0, 50, (n, d)).astype(np.float32)
    s_hi = s_lo + rng.uniform(0.5, 10, (n, d)).astype(np.float32)
    u_lo = rng.uniform(0, 50, (m, d)).astype(np.float32)
    u_hi = u_lo + rng.uniform(0.5, 10, (m, d)).astype(np.float32)
    args = [jnp.asarray(a, dtype) for a in (s_lo, s_hi, u_lo, u_hi)]
    got = bfm_k.bfm_tile_counts(*args, ts=ts, tu=tu, interpret=True)
    want = ref.bfm_tile_counts(*args, ts=ts, tu=tu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("ts,tu", [(8, 128), (64, 64)])
def test_bfm_mask_vs_ref(ts, tu):
    rng = np.random.default_rng(7)
    n, m, d = ts * 2, tu * 3, 2
    s_lo = rng.uniform(0, 30, (n, d)).astype(np.float32)
    s_hi = s_lo + rng.uniform(0.5, 6, (n, d)).astype(np.float32)
    u_lo = rng.uniform(0, 30, (m, d)).astype(np.float32)
    u_hi = u_lo + rng.uniform(0.5, 6, (m, d)).astype(np.float32)
    args = [jnp.asarray(a) for a in (s_lo, s_hi, u_lo, u_hi)]
    got = bfm_k.bfm_mask(*args, ts=ts, tu=tu, interpret=True)
    want = ref.bfm_mask(*args)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ops_padding_matches_core():
    """Wrapper handles non-multiple sizes with sentinel padding."""
    for seed, s_lo, s_hi, u_lo, u_hi in interval_cases(n_cases=6, d=1):
        S = make_regions(s_lo, s_hi)
        U = make_regions(u_lo, u_hi)
        want = int(oracle_mask(s_lo, s_hi, u_lo, u_hi).sum())
        got = bfm_count_pallas(S, U, ts=64, tu=64, interpret=True)
        assert got == want, seed
        mask = bfm_mask_pallas(S, U, ts=64, tu=64, interpret=True)
        assert mask.shape == (S.n, U.n)
        assert int(np.asarray(mask).sum()) == want, seed


@pytest.mark.parametrize("d", [1, 2])
def test_bfm_pairs_pallas_matches_oracle(d):
    rng = np.random.default_rng(29 + d)
    n, m = 100, 90
    s_lo = rng.uniform(0, 30, (n, d)).astype(np.float32)
    s_hi = s_lo + rng.uniform(0.5, 6, (n, d)).astype(np.float32)
    u_lo = rng.uniform(0, 30, (m, d)).astype(np.float32)
    u_hi = u_lo + rng.uniform(0.5, 6, (m, d)).astype(np.float32)
    S, U = make_regions(s_lo, s_hi), make_regions(u_lo, u_hi)
    mask = oracle_mask(s_lo, s_hi, u_lo, u_hi)
    want = {int(a) * m + int(b) for a, b in zip(*np.nonzero(mask))}
    pairs, count = bfm_pairs_pallas(S, U, max_pairs=len(want) + 4,
                                    ts=64, tu=64, interpret=True)
    assert count == len(want)
    arr = np.asarray(pairs)
    arr = arr[arr[:, 0] >= 0]
    assert {int(a) * m + int(b) for a, b in arr} == want


def test_ops_empty_region_sets():
    empty = make_regions(np.zeros((0, 1)), np.zeros((0, 1)))
    S, U = paper_workload(seed=19, n_total=100, alpha=1.0)
    assert bfm_count_pallas(empty, U, interpret=True) == 0
    assert bfm_count_pallas(S, empty, interpret=True) == 0
    assert sbm_count_pallas(empty, U, interpret=True) == 0
    assert bfm_mask_pallas(empty, U, interpret=True).shape == (0, U.n)
    pairs, count = bfm_pairs_pallas(empty, U, max_pairs=3, interpret=True)
    assert count == 0 and pairs.shape == (3, 2)
    assert (np.asarray(pairs) == -1).all()


@pytest.mark.parametrize("block", [128, 512, 2048])
def test_sbm_sweep_kernel_vs_ref(block):
    S, U = paper_workload(seed=13, n_total=block * 2, alpha=20.0)
    is_lo, is_upd = _endpoint_stream(S.lo[:, 0], S.hi[:, 0],
                                     U.lo[:, 0], U.hi[:, 0])
    got = sweep_k.sbm_sweep(is_lo, is_upd, block=block, interpret=True)
    want = ref.sbm_sweep(is_lo, is_upd)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sbm_count_pallas_end_to_end():
    for n_total, alpha in [(1000, 0.01), (2000, 1.0), (3000, 100.0)]:
        S, U = paper_workload(seed=17, n_total=n_total, alpha=alpha)
        want = build_plan(MatchSpec(algo="sbm"), S.n, U.n, 1).count(S, U)
        got = sbm_count_pallas(S, U, block=512, interpret=True)
        assert got == want, (n_total, alpha)


# ---------------------------------------------------------------------------
# fused two-pass emit kernel (kernels.emit)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("route,block", [("resident", 128),
                                         ("resident", 512),
                                         ("streaming", 128),
                                         ("streaming", 512)])
def test_twopass_emit_kernel_bitexact_vs_xla(route, block):
    """Both Pallas pass-2 regimes must reproduce the XLA pass 2
    slot-for-slot, including truncation (saturated offsets) and −1
    padding.  Routes are pinned so the kernel under test is the one
    that runs (tests/test_emit_routing.py covers the router itself)."""
    rng = np.random.default_rng(71)
    for trial in range(4):
        n, m = int(rng.integers(1, 400)), int(rng.integers(1, 400))
        s_lo = rng.uniform(0, 50, (n, 1)).astype(np.float32)
        s_hi = s_lo + rng.uniform(0.5, 10, (n, 1)).astype(np.float32)
        u_lo = rng.uniform(0, 50, (m, 1)).astype(np.float32)
        u_hi = u_lo + rng.uniform(0.5, 10, (m, 1)).astype(np.float32)
        S, U = make_regions(s_lo, s_hi), make_regions(u_lo, u_hi)
        for cap in (1, 9, 4096):
            want_p, want_c = sbm_pairs(S, U, cap)
            got_p, got_c = twopass_pairs_pallas(S, U, cap, block=block,
                                                interpret=True,
                                                route=route)
            assert got_c == want_c, (trial, cap)
            np.testing.assert_array_equal(np.asarray(got_p),
                                          np.asarray(want_p))


@pytest.mark.parametrize("route", ["resident", "streaming"])
def test_twopass_emit_kernel_duplicate_endpoints(route):
    rng = np.random.default_rng(73)
    s_lo = rng.integers(0, 12, (150, 1)).astype(np.float32)
    s_hi = s_lo + rng.integers(1, 5, (150, 1)).astype(np.float32)
    u_lo = rng.integers(0, 12, (130, 1)).astype(np.float32)
    u_hi = u_lo + rng.integers(1, 5, (130, 1)).astype(np.float32)
    S, U = make_regions(s_lo, s_hi), make_regions(u_lo, u_hi)
    mask = oracle_mask(s_lo, s_hi, u_lo, u_hi)
    k = int(mask.sum())
    pairs, count = twopass_pairs_pallas(S, U, k + 5, interpret=True,
                                        route=route)
    assert count == k
    arr = np.asarray(pairs)
    arr = arr[arr[:, 0] >= 0]
    got = {(int(a), int(b)) for a, b in arr}
    assert got == {(int(a), int(b)) for a, b in zip(*np.nonzero(mask))}


def test_twopass_emit_vmem_fallback(monkeypatch):
    """Past both kernel byte budgets the router must take the
    bit-identical XLA pass 2 instead of an uncompilable kernel."""
    import repro.kernels.ops as ops
    S, U = paper_workload(seed=75, n_total=300, alpha=10.0)
    want_p, want_c = sbm_pairs(S, U, 2048)
    monkeypatch.setattr(ops, "_EMIT_VMEM_TABLE_BUDGET", 64)
    got_p, got_c = twopass_pairs_pallas(S, U, 2048, interpret=True)
    assert ops.last_emit_route() == "xla"
    assert got_c == want_c
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))


def test_twopass_emit_zero_capacity_short_circuit():
    """max_pairs == 0 would build a zero-size grid — both kernels must
    short-circuit to the engine's empty (0, 2) contract instead."""
    from repro.kernels import emit as emit_k
    S, U = paper_workload(seed=77, n_total=80, alpha=2.0)
    for route in ("resident", "streaming"):
        pairs, count = twopass_pairs_pallas(S, U, 0, interpret=True,
                                            route=route)
        assert pairs.shape == (0, 2) and pairs.dtype == jnp.int32
        assert count > 0          # the exact K survives the 0-cap buffer
    zeros = jnp.zeros((0,), jnp.int32)
    out = emit_k.twopass_emit(jnp.zeros((1,), jnp.int32), zeros, zeros,
                              zeros, zeros, n=0, m=0, max_pairs=0)
    assert out.shape == (0, 2)


def test_twopass_emit_kernel_empty_sets():
    empty = make_regions(np.zeros((0, 1)), np.zeros((0, 1)))
    S, _ = paper_workload(seed=74, n_total=60, alpha=1.0)
    for A, B in ((empty, S), (S, empty), (empty, empty)):
        pairs, count = twopass_pairs_pallas(A, B, 4, interpret=True)
        assert count == 0 and pairs.shape == (4, 2)
        assert (np.asarray(pairs) == -1).all()
