"""Optimizer substrate: AdamW semantics, schedule shape, int8 gradient
compression unbiasedness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, compress_int8,
                         cosine_schedule, decompress_int8, global_norm)
from repro.optim.compress import compress_tree, decompress_tree


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, state, m = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_weight_decay_decoupled():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=0.5,
                      clip_norm=1e9)
    params = {"w": jnp.asarray([1.0])}
    state = adamw_init(params)
    p2, _, _ = adamw_update(params, {"w": jnp.asarray([0.0])}, state, cfg)
    # zero grad => pure decay: w -= lr*wd*w (m/v stay 0)
    np.testing.assert_allclose(float(p2["w"][0]), 1.0 - 0.1 * 0.5 * 1.0,
                               rtol=1e-5)


def test_schedule_warmup_and_floor():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(cosine_schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(cosine_schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    end = float(cosine_schedule(cfg, jnp.int32(100)))
    np.testing.assert_allclose(end, 0.1, rtol=1e-5)


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 3.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(norm), 6.0, rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0,
                               rtol=1e-5)


def test_int8_compression_unbiased_and_bounded():
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (4096,)) * 0.37
    # unbiased: mean over many stochastic roundings converges to x
    acc = jnp.zeros_like(x)
    n = 64
    for i in range(n):
        q, s = compress_int8(x, jax.random.fold_in(rng, i))
        acc = acc + decompress_int8(q, s)
    err = float(jnp.max(jnp.abs(acc / n - x)))
    amax = float(jnp.max(jnp.abs(x)))
    assert err < 0.3 * amax / 127 * np.sqrt(n) / n + 0.01
    # single-shot error bounded by one quantization step
    q, s = compress_int8(x, rng)
    assert float(jnp.max(jnp.abs(decompress_int8(q, s) - x))) <= float(s) + 1e-6


def test_compress_tree_roundtrip_shapes():
    tree = {"a": jnp.ones((3, 5)), "b": {"c": jnp.zeros((7,))}}
    qs, scales = compress_tree(tree, jax.random.PRNGKey(0))
    out = decompress_tree(qs, scales)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    np.testing.assert_allclose(np.asarray(out["a"]), np.ones((3, 5)),
                               atol=1e-2)


def test_grad_accumulation_matches_monolithic():
    """make_train_step(grad_accum=k) == monolithic batch semantics."""
    import dataclasses
    import jax
    from repro.configs import get_smoke_config
    from repro.launch.steps import make_train_step
    from repro.models import transformer as T
    from repro.optim import adamw_init

    cfg1 = dataclasses.replace(get_smoke_config("llama3_2_3b"),
                               remat=False)
    cfg2 = dataclasses.replace(cfg1, grad_accum=2)
    params = T.init_params(cfg1, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (4, 33), 0, cfg1.vocab)}
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    p1, _, m1 = jax.jit(make_train_step(cfg1, ocfg))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg2, ocfg))(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        # first-step AdamW normalizes by sqrt(v)+eps, amplifying bf16
        # forward noise where v ~ 0 — tolerance reflects that, not a
        # semantic difference (grad means are mathematically equal).
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=2e-3)
