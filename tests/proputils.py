"""Tiny property-testing harness (hypothesis is not installed offline).

``cases()`` generates deterministic randomized instances across a seed
sweep; failures report the generating seed so they replay exactly.
"""
from __future__ import annotations

import numpy as np

from repro.core import MatchSpec, build_plan


def plan_count(S, U, algo="sbm", *, max_pairs=None, **kw):
    """Exact K via a fixed-capacity xla plan (the tests' reference
    path; ``max_pairs`` never affects the count)."""
    spec = MatchSpec(algo=algo, backend="xla", capacity="fixed",
                     max_pairs=max_pairs or 1, **kw)
    return build_plan(spec, S.n, U.n, S.d).count(S, U)


def plan_pairs(S, U, max_pairs, algo="sbm", **kw):
    """(PairsResult, exact K) via a fixed-capacity xla plan: the buffer
    is exactly ``(max_pairs, 2)`` and truncation is reported by K."""
    spec = MatchSpec(algo=algo, backend="xla", capacity="fixed",
                     max_pairs=max_pairs, **kw)
    return build_plan(spec, S.n, U.n, S.d).pairs(S, U)


def interval_cases(n_cases: int = 25, max_n: int = 400, max_m: int = 400,
                   d: int = 1, seed0: int = 1234,
                   include_empty: bool = False):
    """Yield (seed, s_lo, s_hi, u_lo, u_hi) randomized instances.

    Mix of regimes: dense overlap, sparse, duplicated coordinates
    (integer grids — tie-handling stress), tiny and degenerate-but-valid
    (length epsilon) intervals.  ``include_empty`` prepends the three
    empty-set cases (S empty, U empty, both empty).
    """
    if include_empty:
        empty = np.zeros((0, d), np.float32)
        rng = np.random.default_rng(seed0 - 1)
        lo = rng.uniform(0, 50, (5, d)).astype(np.float32)
        hi = lo + rng.uniform(0.5, 5.0, (5, d)).astype(np.float32)
        yield seed0 - 1, empty, empty, lo, hi
        yield seed0 - 2, lo, hi, empty, empty
        yield seed0 - 3, empty, empty, empty.copy(), empty.copy()
    for case in range(n_cases):
        seed = seed0 + case
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, max_n))
        m = int(rng.integers(1, max_m))
        regime = case % 5
        if regime == 0:      # uniform floats, medium overlap
            space, length = 100.0, rng.uniform(0.5, 10.0)
        elif regime == 1:    # sparse
            space, length = 10000.0, rng.uniform(0.01, 0.5)
        elif regime == 2:    # dense
            space, length = 10.0, rng.uniform(1.0, 8.0)
        elif regime == 3:    # integer endpoints => many exact ties
            s_lo = rng.integers(0, 50, (n, d)).astype(np.float32)
            s_hi = s_lo + rng.integers(1, 8, (n, d)).astype(np.float32)
            u_lo = rng.integers(0, 50, (m, d)).astype(np.float32)
            u_hi = u_lo + rng.integers(1, 8, (m, d)).astype(np.float32)
            yield seed, s_lo, s_hi, u_lo, u_hi
            continue
        else:                # mixed lengths incl. near-degenerate
            space = 100.0
            s_lo = rng.uniform(0, space, (n, d)).astype(np.float32)
            s_hi = s_lo + rng.uniform(1e-3, 20.0, (n, d)).astype(np.float32)
            u_lo = rng.uniform(0, space, (m, d)).astype(np.float32)
            u_hi = u_lo + rng.uniform(1e-3, 20.0, (m, d)).astype(np.float32)
            yield seed, s_lo, s_hi, u_lo, u_hi
            continue
        s_lo = rng.uniform(0, space, (n, d)).astype(np.float32)
        s_hi = (s_lo + length).astype(np.float32)
        u_lo = rng.uniform(0, space, (m, d)).astype(np.float32)
        u_hi = (u_lo + length).astype(np.float32)
        yield seed, s_lo, s_hi, u_lo, u_hi


def oracle_mask(s_lo, s_hi, u_lo, u_hi):
    """Numpy oracle: half-open d-rectangle overlap mask (n, m)."""
    ok = np.logical_and(s_lo[:, None, :] < u_hi[None, :, :],
                        u_lo[None, :, :] < s_hi[:, None, :])
    return ok.all(axis=-1)
