"""Seeded retrace-pass defects — capacity resolvers that recompile
forever.  Each is driven through the adversarial K stream and must blow
the O(lg K) distinct-capacity bound.
"""
from repro.analysis import audit_grow_bound


def _exact_resolver(report, target):
    # "grow" that actually resizes to exactly K: every K drift is a new
    # static shape, i.e. a recompile per distinct K
    def factory():
        return lambda k: max(k, 1)

    audit_grow_bound(factory, max_k=1 << 20, target=target,
                     report=report)


def _quantized_linear_resolver(report, target):
    # rounding to 1024-slot quanta still grows linearly in K — 1024
    # distinct capacities by 1e6, vs ~22 for the doubling ladder
    def factory():
        return lambda k: -(-max(k, 1) // 1024) * 1024

    audit_grow_bound(factory, max_k=1 << 20, target=target,
                     report=report)


CASES = [
    dict(name="exact_growth_resolver", pass_name="retrace",
         code="R_GROW_BOUND", audit=_exact_resolver),
    dict(name="quantized_linear_resolver", pass_name="retrace",
         code="R_GROW_BOUND", audit=_quantized_linear_resolver),
]
