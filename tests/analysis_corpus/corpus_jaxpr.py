"""Seeded jaxpr-pass defects — each must be flagged by the auditor.

The int32 case is the repo's own latent hazard at a scale past its
dynamic guard: ``ops._compact_mask_pairs`` ravels the (n, m) mask to
flat int32 indices, which alias once n*m crosses INT32_MAX — exactly
what ``bfm_pairs_pallas`` refuses at run time and the auditor must see
statically.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import audit_fn
from repro.kernels import ops


def _int32_overflow(report, target):
    # 60k x 60k = 3.6e9 > INT32_MAX: the ravel's flat index space
    # no longer fits the int32 iota behind nonzero()
    mask = jax.ShapeDtypeStruct((60_000, 60_000), jnp.bool_)
    audit_fn(ops._compact_mask_pairs, (mask,), target=target,
             report=report, static_kwargs=dict(max_pairs=4096),
             check_rank=False)


def _host_callback(report, target):
    def hot_path(x):
        # a host round-trip hiding inside a "pure" helper
        y = jax.pure_callback(
            lambda a: np.asarray(a) * 2,
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return y + 1

    audit_fn(hot_path, (jax.ShapeDtypeStruct((128,), jnp.float32),),
             target=target, report=report, check_rank=False)


def _device_transfer(report, target):
    dev = jax.devices()[0]

    def hot_path(x):
        # explicit placement inside a traced path: a real transfer,
        # unlike the benign constant device_put the auditor ignores
        return jax.device_put(x, dev) + 1

    audit_fn(hot_path, (jax.ShapeDtypeStruct((128,), jnp.float32),),
             target=target, report=report, check_rank=False)


def _rank_promotion(report, target):
    def hot_path(a, b):
        return a + b      # (64, 1) + (32,): implicit rank promotion

    audit_fn(hot_path, (jax.ShapeDtypeStruct((64, 1), jnp.float32),
                        jax.ShapeDtypeStruct((32,), jnp.float32)),
             target=target, report=report)


def _weak_output(report, target):
    def hot_path(x):
        # result dtype hangs off a Python literal only — weak-typed
        # output, silently promotable by the first caller-side op
        return jnp.full((x.shape[0],), 1.5)

    audit_fn(hot_path, (jax.ShapeDtypeStruct((64,), jnp.float32),),
             target=target, report=report, check_rank=False)


def _dtype_contract(report, target):
    def pairs_like(x):
        return x.astype(jnp.float32)   # contract says int32 pairs

    audit_fn(pairs_like, (jax.ShapeDtypeStruct((64, 2), jnp.int32),),
             target=target, report=report, check_rank=False,
             out_dtypes=(np.int32,))


def _f64_promotion(report, target):
    from jax.experimental import enable_x64

    def hot_path(x):
        return x.astype(jnp.float64).cumsum()

    with enable_x64():
        audit_fn(hot_path, (jax.ShapeDtypeStruct((64,), jnp.float32),),
                 target=target, report=report, check_rank=False)


CASES = [
    dict(name="int32_mask_ravel_overflow", pass_name="jaxpr",
         code="J_INT32_INDEX", audit=_int32_overflow),
    dict(name="pure_callback_in_hot_path", pass_name="jaxpr",
         code="J_CALLBACK", audit=_host_callback),
    dict(name="device_put_in_hot_path", pass_name="jaxpr",
         code="J_CALLBACK", audit=_device_transfer),
    dict(name="implicit_rank_promotion", pass_name="jaxpr",
         code="J_RANK_PROMOTION", audit=_rank_promotion),
    dict(name="weak_typed_output", pass_name="jaxpr",
         code="J_WEAK_OUT", audit=_weak_output),
    dict(name="pairs_dtype_contract", pass_name="jaxpr",
         code="J_DTYPE_CONTRACT", audit=_dtype_contract),
    dict(name="float64_promotion", pass_name="jaxpr",
         code="J_F64", audit=_f64_promotion),
]
