"""Seeded lint-pass defects — source files under ``lint_defects/`` with
the banned patterns; the AST lint must flag each.
"""
from pathlib import Path

from repro.analysis import lint_source

_DEFECTS = Path(__file__).parent / "lint_defects"


def _deprecated_calls(report, target):
    path = _DEFECTS / "uses_deprecated.py"
    lint_source(path.read_text(), path=str(path), report=report)


def _missing_empty_guard(report, target):
    path = _DEFECTS / "missing_guard.py"
    lint_source(path.read_text(), path=str(path), report=report)


CASES = [
    dict(name="deprecated_shim_calls", pass_name="lint",
         code="L_DEPRECATED", audit=_deprecated_calls),
    dict(name="pallas_wrapper_missing_empty_guard", pass_name="lint",
         code="L_EMPTY_GUARD", audit=_missing_empty_guard),
]
