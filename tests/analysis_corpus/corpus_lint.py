"""Seeded lint-pass defects — source files under ``lint_defects/`` with
the banned patterns; the AST lint must flag each.
"""
from pathlib import Path

from repro.analysis import lint_source

_DEFECTS = Path(__file__).parent / "lint_defects"


def _deprecated_calls(report, target):
    path = _DEFECTS / "uses_deprecated.py"
    lint_source(path.read_text(), path=str(path), report=report)


def _missing_empty_guard(report, target):
    path = _DEFECTS / "missing_guard.py"
    lint_source(path.read_text(), path=str(path), report=report)


def _trivial_module_docstring(report, target):
    # linted under a virtual serve path: the docstring rule keys on the
    # module's location, and this defect models a serve module shipped
    # with a one-word docstring instead of its contract.
    path = _DEFECTS / "bare_serve_module.py"
    lint_source(path.read_text(),
                path="src/repro/serve/bare_serve_module.py",
                report=report)


CASES = [
    dict(name="deprecated_shim_calls", pass_name="lint",
         code="L_DEPRECATED", audit=_deprecated_calls),
    dict(name="pallas_wrapper_missing_empty_guard", pass_name="lint",
         code="L_EMPTY_GUARD", audit=_missing_empty_guard),
    dict(name="serve_module_trivial_docstring", pass_name="lint",
         code="L_MODULE_DOCSTRING", audit=_trivial_module_docstring),
]
