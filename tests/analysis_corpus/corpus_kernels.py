"""Seeded kernel-pass defects — real ``pallas_call`` wrappers with the
bugs the static audit exists to catch.  Each wrapper is traced
abstractly (never executed) and its captured specs audited.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis import (audit_emit_route_parity, audit_kernel_capture,
                            trace_kernel)


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _oob_wrapper(x):
    # out_shape holds 2 blocks of 512 but the grid walks 4: the last
    # two grid steps write blocks [1024, 1536) and [1536, 2048) of a
    # (1, 1024) array
    return pl.pallas_call(
        _copy_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((1, 512), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, 512), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, 1024), jnp.float32),
    )(x)


def _oob_index_map(report, target):
    caps = trace_kernel(_oob_wrapper,
                        jax.ShapeDtypeStruct((1, 2048), jnp.float32))
    for cap in caps:
        audit_kernel_capture(cap, report=report)


def _hazard_wrapper(x):
    # i // 2 maps grid steps (0, 1) and (2, 3) onto the same output
    # blocks: last-write-wins on TPU, a race anywhere else
    return pl.pallas_call(
        _copy_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((1, 512), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, 512), lambda i: (0, i // 2)),
        out_shape=jax.ShapeDtypeStruct((1, 1024), jnp.float32),
    )(x)


def _write_hazard(report, target):
    caps = trace_kernel(_hazard_wrapper,
                        jax.ShapeDtypeStruct((1, 2048), jnp.float32))
    for cap in caps:
        audit_kernel_capture(cap, report=report)


def _vmem_wrapper(x):
    # the whole 64 MiB operand pinned VMEM-resident (plus the matching
    # output block): 128 MiB per program against a 16 MiB core
    return pl.pallas_call(
        _copy_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec(x.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec(x.shape, lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
    )(x)


def _vmem_budget(report, target):
    caps = trace_kernel(_vmem_wrapper,
                        jax.ShapeDtypeStruct((4096, 4096), jnp.float32))
    for cap in caps:
        audit_kernel_capture(cap, report=report)


def _route_drift(report, target):
    # a byte model that drifted from the kernels: it forgets the
    # double-buffer factor of the streaming window
    from repro.kernels import emit as emit_kernel
    from repro.kernels import ops

    real = ops.emit_route_bytes

    def drifted(n, m, *, block=emit_kernel.DEF_BLOCK):
        e = n + m
        bl = emit_kernel.lane_pad(block)
        win = emit_kernel.stream_window(bl)
        return {"resident": 4 * (3 * (e + 1) + e),
                "streaming": 4 * e + 8 * win * 4,   # dropped the 2x
                "csr": 4 * (8 * win + 2 * bl)}

    ops.emit_route_bytes = drifted
    try:
        audit_emit_route_parity(report, n=4000, m=3000, max_pairs=8192)
    finally:
        ops.emit_route_bytes = real


CASES = [
    dict(name="oob_output_index_map", pass_name="kernel",
         code="K_OOB_INDEX_MAP", audit=_oob_index_map),
    dict(name="write_write_hazard", pass_name="kernel",
         code="K_WRITE_HAZARD", audit=_write_hazard),
    dict(name="vmem_over_budget", pass_name="kernel",
         code="K_VMEM_BUDGET", audit=_vmem_budget),
    dict(name="emit_route_model_drift", pass_name="kernel",
         code="K_ROUTE_DRIFT", audit=_route_drift),
]
