"""Seeded lint defect: internal code still calling the deprecated
shims.  Scanned as text by the corpus lint cases; never imported."""
from repro.core.dd_match import match_count, match_pairs
from repro.core.distributed import distributed_sbm_count


def count_overlaps(S, U):
    return match_count(S, U, algo="sbm")


def enumerate_overlaps(S, U, cap):
    pairs, k = match_pairs(S, U, cap, algo="sbm")
    total = distributed_sbm_count(S, U)
    return pairs, k, total
