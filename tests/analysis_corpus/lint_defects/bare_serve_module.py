"""Helpers."""
import threading

_LOCK = threading.Lock()


def swap(ref, value):
    with _LOCK:
        old, ref[0] = ref[0], value
    return old
