"""Seeded lint defect: a pallas_call wrapper taking ``max_pairs`` with
no ``max_pairs == 0`` short-circuit — a zero-size grid is not a legal
``pallas_call``.  Scanned as text by the corpus lint cases; never
imported."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def emit_pairs(x, max_pairs: int, block: int = 512):
    grid = (max_pairs // block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, max_pairs), jnp.int32),
    )(x)
