"""Core DDM matching: cross-algorithm agreement + property tests.

The paper's central correctness requirement (§2): every overlapping
(subscription, update) pair is reported exactly once.  We check all
algorithm variants against a numpy brute-force oracle across randomized
regimes (including exact-tie endpoint grids, which stress the half-open
semantics and the hi-before-lo sweep ordering).
"""
import numpy as np
import pytest

from repro.core import (Regions, make_regions, paper_workload,
                        koln_like_workload, pairs_to_set)
from repro.core import sbm, itm, brute, grid

from proputils import interval_cases, oracle_mask, plan_count, plan_pairs

COUNT_ALGOS = ("bfm", "gbm", "sbm", "sbm_chunked", "sbm_binary", "itm")
PAIR_ALGOS = ("bfm", "sbm", "itm")


def _regions(s_lo, s_hi, u_lo, u_hi):
    return make_regions(s_lo, s_hi), make_regions(u_lo, u_hi)


@pytest.mark.parametrize("algo", COUNT_ALGOS)
def test_count_matches_oracle_1d(algo):
    for seed, s_lo, s_hi, u_lo, u_hi in interval_cases(n_cases=20, d=1):
        S, U = _regions(s_lo, s_hi, u_lo, u_hi)
        want = int(oracle_mask(s_lo, s_hi, u_lo, u_hi).sum())
        got = plan_count(S, U, algo=algo)
        assert got == want, f"seed={seed} algo={algo}: {got} != {want}"


@pytest.mark.parametrize("algo", PAIR_ALGOS)
def test_pairs_match_oracle_1d(algo):
    for seed, s_lo, s_hi, u_lo, u_hi in interval_cases(n_cases=10, d=1):
        S, U = _regions(s_lo, s_hi, u_lo, u_hi)
        mask = oracle_mask(s_lo, s_hi, u_lo, u_hi)
        want = {(int(a), int(b)) * 1 for a, b in zip(*np.nonzero(mask))}
        want = {int(a) * U.n + int(b) for a, b in zip(*np.nonzero(mask))}
        cap = max(int(mask.sum()), 1) + 7
        pairs, count = plan_pairs(S, U, max_pairs=cap, algo=algo)
        assert int(count) == len(want), f"seed={seed}"
        assert pairs_to_set(pairs, U.n) == want, f"seed={seed} algo={algo}"


@pytest.mark.parametrize("algo", ("bfm", "sbm", "itm"))
@pytest.mark.parametrize("d", (2, 3))
def test_count_matches_oracle_dd(algo, d):
    for seed, s_lo, s_hi, u_lo, u_hi in interval_cases(n_cases=8, d=d,
                                                       max_n=150,
                                                       max_m=150):
        S, U = _regions(s_lo, s_hi, u_lo, u_hi)
        want = int(oracle_mask(s_lo, s_hi, u_lo, u_hi).sum())
        got = plan_count(S, U, algo=algo)
        assert got == want, f"seed={seed} d={d} algo={algo}"


def test_empty_sets_all_algos():
    """Empty S or U: count 0 and a well-formed −1-padded pair buffer
    (the old sbm path crashed on jnp.max of a zero-size array)."""
    empty = make_regions(np.zeros((0, 1)), np.zeros((0, 1)))
    full = make_regions(np.array([[1.0], [4.0]]), np.array([[3.0], [9.0]]))
    for algo in COUNT_ALGOS:
        assert plan_count(empty, full, algo=algo) == 0, algo
        assert plan_count(full, empty, algo=algo) == 0, algo
        assert plan_count(empty, empty, algo=algo) == 0, algo
    for algo in PAIR_ALGOS:
        for S, U in ((empty, full), (full, empty), (empty, empty)):
            pairs, count = plan_pairs(S, U, max_pairs=3, algo=algo)
            assert int(count) == 0, algo
            assert pairs.shape == (3, 2), algo
            assert (np.asarray(pairs) == -1).all(), algo


def test_halfopen_touching_intervals_do_not_match():
    # [0,1) and [1,2) share only the boundary point -> no overlap
    S = make_regions(np.array([[0.0]]), np.array([[1.0]]))
    U = make_regions(np.array([[1.0]]), np.array([[2.0]]))
    for algo in COUNT_ALGOS:
        assert plan_count(S, U, algo=algo) == 0, algo
    # and the mirror case
    for algo in COUNT_ALGOS:
        assert plan_count(U, S, algo=algo) == 0, algo


def test_identical_intervals_match():
    S = make_regions(np.array([[3.0], [3.0]]), np.array([[7.0], [7.0]]))
    U = make_regions(np.array([[3.0]]), np.array([[7.0]]))
    for algo in COUNT_ALGOS:
        assert plan_count(S, U, algo=algo) == 2, algo


def test_containment_and_equal_uppers():
    # u inside s; equal upper endpoints; equal lower endpoints
    S = make_regions(np.array([[0.0], [2.0], [4.0]]),
                     np.array([[10.0], [6.0], [6.0]]))
    U = make_regions(np.array([[1.0], [2.0], [5.0]]),
                     np.array([[2.0], [6.0], [6.0]]))
    mask = oracle_mask(np.asarray(S.lo), np.asarray(S.hi),
                       np.asarray(U.lo), np.asarray(U.hi))
    want = int(mask.sum())
    for algo in COUNT_ALGOS:
        assert plan_count(S, U, algo=algo) == want, algo


def test_paper_workload_alpha_scaling():
    """E[K] grows ~linearly with alpha (paper §5: alpha is an indirect
    measure of the number of intersections)."""
    k = {}
    for alpha in (0.01, 1.0, 100.0):
        S, U = paper_workload(seed=11, n_total=4000, alpha=alpha)
        k[alpha] = plan_count(S, U, algo="sbm")
    assert k[0.01] < k[1.0] < k[100.0]
    # alpha=100 with N=4000: l = alpha*L/N, E[K] ~ n*m*2l/L = alpha*N/2
    approx = 100.0 * 4000 / 2
    assert 0.5 * approx < k[100.0] < 2.0 * approx


def test_koln_like_workload_runs():
    S, U = koln_like_workload(seed=1, n_positions=2000)
    a = plan_count(S, U, algo="sbm")
    b = plan_count(S, U, algo="sbm_binary")
    c = plan_count(S, U, algo="itm")
    assert a == b == c
    assert a >= S.n  # every region overlaps itself's twin at least


def test_gbm_ncells_invariance():
    """GBM must report identical K for any ncells (paper: ncells only
    affects speed; the res-set/first-cell dedup guards correctness)."""
    S, U = paper_workload(seed=3, n_total=3000, alpha=10.0)
    want = plan_count(S, U, algo="sbm")
    for ncells in (7, 64, 500, 3000):
        assert grid.gbm_count(S, U, ncells=ncells) == want, ncells


def test_sbm_chunk_count_invariance():
    """Alg. 6/7: result is independent of the number of segments P."""
    S, U = paper_workload(seed=4, n_total=2048, alpha=5.0)
    want = sbm.sbm_count_sweep(S, U)
    for p in (1, 2, 3, 8, 64, 117):
        assert sbm.sbm_count_chunked(S, U, p=p) == want, p


def test_itm_swap_invariance():
    S, U = paper_workload(seed=6, n_total=1000, alpha=2.0)
    assert itm.itm_count(S, U, swap="S") == itm.itm_count(S, U, swap="U")


def test_itm_tree_invariants():
    """maxupper/minlower really bound their subtrees."""
    S, _ = paper_workload(seed=7, n_total=600, alpha=1.0)
    T = itm.build_tree(S)
    lo = np.asarray(T.lo)
    hi = np.asarray(T.hi)
    mu = np.asarray(T.maxupper)
    ml = np.asarray(T.minlower)
    M = lo.shape[0] - 1
    for k in range(1, M + 1):
        kids = [c for c in (2 * k, 2 * k + 1) if c <= M]
        want_mu = max([hi[k]] + [mu[c] for c in kids])
        want_ml = min([lo[k]] + [ml[c] for c in kids])
        assert mu[k] == want_mu and ml[k] == want_ml, k
    # in-order traversal of lo is sorted (BST property)
    def inorder(k, out):
        if k > M:
            return
        inorder(2 * k, out)
        if np.isfinite(lo[k]):
            out.append(lo[k])
        inorder(2 * k + 1, out)
    out = []
    import sys
    sys.setrecursionlimit(10000)
    inorder(1, out)
    assert out == sorted(out)


def test_bfm_tiled_equals_direct():
    for seed, s_lo, s_hi, u_lo, u_hi in interval_cases(n_cases=5, d=1):
        S, U = _regions(s_lo, s_hi, u_lo, u_hi)
        direct = int(np.asarray(brute.bfm_mask(S, U)).sum())
        for tile in (1, 7, 64, 4096):
            assert brute.bfm_count(S, U, tile=tile) == direct, (seed, tile)


def test_pairs_overflow_reports_true_count():
    S, U = paper_workload(seed=9, n_total=500, alpha=50.0)
    true_k = plan_count(S, U, algo="sbm")
    pairs, count = plan_pairs(S, U, max_pairs=5, algo="sbm")
    assert int(count) == true_k and true_k > 5
    assert pairs.shape == (5, 2)
