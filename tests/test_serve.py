"""The DDM serving layer: swap protocol, admission, batching, tenancy.

The swap-protocol tests are the load-bearing ones: a reader querying
mid-rebuild must see either the old or the new region set *in full* —
never a torn mix — and steady-state churn must never retrace.
"""
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import DDMService, MatchSpec, paper_workload
from repro.core.engine import build_plan
from repro.core.regions import Regions, make_regions
from repro.analysis.retrace import no_retrace
from repro.serve import (AdmissionError, AdmissionPolicy, BatchPolicy,
                         DDMServer)
from repro.serve.tenancy import pad_moves_pow2


def _cluster_regions(n, center, width=10.0, d=1):
    lo = np.full((n, d), center - width / 2, np.float32)
    lo += np.linspace(0, 1, n, dtype=np.float32)[:, None]
    return make_regions(lo, lo + width)


def _server(**kw):
    kw.setdefault("batch", BatchPolicy(max_batch=16, max_delay_s=1e-3))
    return DDMServer(**kw)


def _add(server, name, n=64, seed=0, d=1, cap_hint=256):
    S, U = paper_workload(seed=seed, n_total=2 * n, alpha=5.0, d=d)
    return server.add_tenant(name, S, U, cap_hint=cap_hint)


# ---------------------------------------------------------------------------
# query correctness + staleness semantics
# ---------------------------------------------------------------------------

def test_query_matches_brute_oracle_every_tick():
    server = _server()
    t = _add(server, "a", n=128, seed=3, d=2)
    rng = np.random.default_rng(0)
    for tick in range(5):
        idx = rng.choice(128, size=16, replace=False)
        lo = rng.uniform(0, 9e5, (16, 2)).astype(np.float32)
        hi = lo + rng.uniform(1, 5e3, (16, 2)).astype(np.float32)
        server.update_regions("a", "sub", idx, lo, hi)
        server.pump()                       # rebuild → staleness 0
        for target in ("sub", "upd"):
            q_lo = rng.uniform(0, 9.9e5, (2,)).astype(np.float32)
            q_hi = q_lo + 1e4
            res = server.query("a", target, q_lo, q_hi)
            assert res.staleness == 0
            want = t.live.oracle_ids(target, q_lo, q_hi)
            assert res.id_set() == want, f"tick={tick} target={target}"


def test_stale_reads_are_exact_for_their_version():
    """Mid-churn answers match the *snapshot's* oracle, with the
    staleness bound surfaced on the response."""
    server = _server()
    t = _add(server, "a", n=128, seed=1)
    rng = np.random.default_rng(1)
    old_snap = t.live
    idx = rng.choice(128, size=32, replace=False)
    lo = rng.uniform(0, 9e5, (32, 1)).astype(np.float32)
    server.update_regions("a", "sub", idx, lo, lo + 100)
    # no rebuild pumped yet: the published snapshot is one version behind
    q_lo, q_hi = np.float32([0.0]), np.float32([9.9e5])
    fut = server.submit("a", "sub", q_lo, q_hi)
    server.pump(rebuilds=False)
    res = fut.result(timeout=10)
    assert res.staleness == 1
    assert res.version == old_snap.version
    assert res.id_set() == old_snap.oracle_ids("sub", q_lo, q_hi)
    server.pump()                           # now publish
    res2 = server.query("a", "sub", q_lo, q_hi)
    assert res2.staleness == 0
    assert res2.id_set() == t.live.oracle_ids("sub", q_lo, q_hi)


# ---------------------------------------------------------------------------
# the swap protocol: never a torn mix, readers never blocked
# ---------------------------------------------------------------------------

def test_reader_mid_rebuild_sees_full_old_or_full_new_set():
    """Property: every response equals the complete region set of SOME
    version — cluster A (even versions) or cluster B (odd) — while a
    writer thread churns ALL regions back and forth.  A torn read (some
    regions at A, some at B) returns a strict subset and fails."""
    n = 48
    A, B = 1e3, 5e5
    S = _cluster_regions(n, A)
    U = _cluster_regions(n, B)
    server = _server(batch=BatchPolicy(max_batch=8, max_delay_s=5e-4))
    t = server.add_tenant("t", S, U, cap_hint=128)
    all_ids = set(range(n))
    box_a = (np.float32([A - 100]), np.float32([A + 100]))

    def move_all(center, rng):
        lo = np.full((n, 1), center - 50, np.float32) \
            + rng.uniform(0, 1, (n, 1)).astype(np.float32)
        server.update_regions("t", "sub", np.arange(n), lo, lo + 10)

    def settle():
        deadline = time.time() + 60
        while t.staleness and time.time() < deadline:
            time.sleep(1e-3)
        assert t.staleness == 0

    server.start()
    try:
        # warm both clusters' compiled paths BEFORE the timed hammer (a
        # first query compiles for seconds on a 1-core box) and leave
        # the store at an even version (cluster A) so version parity
        # below tracks the writer's local counter
        wrng = np.random.default_rng(3)
        assert server.query("t", "sub", *box_a, timeout=120).id_set() \
            == all_ids
        move_all(B, wrng)
        settle()
        assert server.query("t", "sub", *box_a, timeout=120).id_set() \
            == set()
        move_all(A, wrng)
        settle()
        assert t.store_version == 2

        stop = threading.Event()
        errors = []

        def writer():
            rng = np.random.default_rng(2)
            v = 0
            while not stop.is_set() and v < 40:
                v += 1
                move_all(B if v % 2 else A, rng)
                time.sleep(2e-3)

        wt = threading.Thread(target=writer)
        wt.start()
        t_end = time.time() + 3.0
        checked = 0
        while time.time() < t_end:
            try:
                res = server.query("t", "sub", *box_a, timeout=30)
            except AdmissionError:
                continue
            got = res.id_set()
            # full set at A (even version incl. 0) or empty (odd): any
            # proper subset means the reader saw a torn region set
            if got != all_ids and got != set():
                errors.append((res.version, len(got)))
            # version parity must agree with the cluster the answer saw
            want = all_ids if res.version % 2 == 0 else set()
            if got != want:
                errors.append(("version-mismatch", res.version, len(got)))
            checked += 1
        stop.set()
        wt.join()
        assert not errors, errors[:5]
        assert checked > 20, f"only {checked} mid-churn reads exercised"
    finally:
        server.stop()


def test_queries_complete_while_rebuild_in_flight():
    """Hold the rebuild worker mid-build via the hook; queries must
    still complete (from the old snapshot, staleness ≥ 1)."""
    server = _server(batch=BatchPolicy(max_batch=8, max_delay_s=5e-4))
    t = _add(server, "a", n=128, seed=5)
    gate = threading.Event()
    in_rebuild = threading.Event()

    def hook(phase, name):
        if phase == "capture":
            in_rebuild.set()
            assert gate.wait(timeout=30)

    server.rebuild_hook = hook
    server.start()
    try:
        old_version = t.live.version
        rng = np.random.default_rng(7)
        idx = rng.choice(128, size=16, replace=False)
        lo = rng.uniform(0, 9e5, (16, 1)).astype(np.float32)
        server.update_regions("a", "sub", idx, lo, lo + 100)
        assert in_rebuild.wait(timeout=30), "rebuild never started"
        # rebuild is now parked mid-build; queries must not block on it
        res = server.query("a", "sub", np.float32([0.0]),
                           np.float32([9.9e5]), timeout=10)
        assert res.staleness >= 1
        assert res.version == old_version
        gate.set()
        deadline = time.time() + 30
        while t.staleness and time.time() < deadline:
            time.sleep(1e-3)
        assert t.staleness == 0, "rebuild never published after release"
    finally:
        gate.set()
        server.stop()


def test_snapshot_immutable_under_store_churn():
    svc = DDMService(*paper_workload(seed=9, n_total=128, alpha=5.0))
    snap = svc.snapshot()
    before = snap.s_lo.copy()
    svc.apply_moves("sub", np.arange(64),
                    np.zeros((64, 1), np.float32),
                    np.ones((64, 1), np.float32))
    assert svc.version == 1 and snap.version == 0
    np.testing.assert_array_equal(snap.s_lo, before)
    # and the service's own store really moved
    assert not np.array_equal(svc.s_lo, before)


# ---------------------------------------------------------------------------
# retrace discipline + plan memoization per (tenant, spec)
# ---------------------------------------------------------------------------

def test_zero_steady_state_retraces_per_tenant():
    from repro.serve.harness import run_churn
    # run_churn wraps its steady-state ticks in no_retrace and raises
    # RetraceError on any violation
    stats = run_churn(tenants=2, n_total=512, ticks=3, warmup=1,
                      moves_per_tick=16, queries_per_tick=12,
                      max_batch=16, cap_hint=256, seed=4)
    assert stats["parity_checks"] > 0


def test_plan_memoized_per_tenant_spec_key():
    spec = MatchSpec(algo="itm", capacity="grow", max_pairs=64)
    p_a1 = build_plan(spec, 64, 64, 1, key=("serve", 0, "a"))
    p_a2 = build_plan(spec, 64, 64, 1, key=("serve", 0, "a"))
    p_b = build_plan(spec, 64, 64, 1, key=("serve", 0, "b"))
    assert p_a1 is p_a2                 # one plan per (tenant, spec)
    assert p_a1 is not p_b              # tenants never share capacities
    # and a second server's same-named tenant is again distinct
    assert build_plan(spec, 64, 64, 1,
                      key=("serve", 1, "a")) is not p_a1


def test_explicit_query_steady_state_no_retrace():
    server = _server()
    t = _add(server, "a", n=128, seed=6, cap_hint=256)
    rng = np.random.default_rng(0)

    def one_round():
        idx = rng.choice(128, size=8, replace=False)
        lo = rng.uniform(0, 9e5, (8, 1)).astype(np.float32)
        server.update_regions("a", "sub", idx, lo, lo + 50)
        for target in ("sub", "upd"):
            server.query("a", target, np.float32([1e3]),
                         np.float32([5e5]))
        server.pump()

    for _ in range(2):                  # warm every executable + cap
        one_round()
    with no_retrace(t.plan):
        for _ in range(3):
            one_round()


# ---------------------------------------------------------------------------
# admission control + fairness + batching
# ---------------------------------------------------------------------------

def test_admission_reject_when_queue_full():
    server = _server(admission=AdmissionPolicy(max_queue=4, shed="reject"))
    _add(server, "a")
    box = (np.float32([0.0]), np.float32([1e5]))
    futs = [server.submit("a", "sub", *box) for _ in range(4)]
    with pytest.raises(AdmissionError, match="tenant 'a'.*queue full"):
        server.submit("a", "sub", *box)
    m = server.metrics_dict()["tenants"]["a"]["counters"]
    assert m["rejected"] == 1 and m["submitted"] == 4
    server.pump()
    assert all(f.done() for f in futs)


def test_admission_drop_oldest_fails_evicted_future():
    server = _server(admission=AdmissionPolicy(max_queue=3,
                                               shed="drop_oldest"))
    _add(server, "a")
    box = (np.float32([0.0]), np.float32([1e5]))
    futs = [server.submit("a", "sub", *box) for _ in range(5)]
    # the two oldest were evicted, their futures carry AdmissionError
    for f in futs[:2]:
        with pytest.raises(AdmissionError, match="drop_oldest"):
            f.result(timeout=1)
    server.pump()
    for f in futs[2:]:
        assert f.result(timeout=1).ids is not None
    m = server.metrics_dict()["tenants"]["a"]["counters"]
    assert m["shed"] == 2 and m["completed"] == 3


def test_fairness_light_tenant_not_starved_by_flood():
    server = _server(batch=BatchPolicy(max_batch=8),
                     admission=AdmissionPolicy(max_queue=512))
    _add(server, "heavy", seed=1)
    _add(server, "light", seed=2)
    box = (np.float32([0.0]), np.float32([1e5]))
    heavy = [server.submit("heavy", "sub", *box) for _ in range(64)]
    light = [server.submit("light", "sub", *box) for _ in range(4)]
    served = server._dispatch_once(force=True)
    # one fairness round: every stream gets at most max_batch slots, so
    # the flood cannot crowd the light tenant out of the round
    assert all(f.done() for f in light)
    assert sum(f.done() for f in heavy) == 8
    assert served == 12
    server.pump()
    assert all(f.done() for f in heavy)


def test_batch_coalescing_and_occupancy_metric():
    server = _server(batch=BatchPolicy(max_batch=16))
    _add(server, "a")
    box = (np.float32([0.0]), np.float32([1e5]))
    futs = [server.submit("a", "sub", *box) for _ in range(10)]
    server.pump(rebuilds=False)
    assert all(f.done() for f in futs)
    m = server.metrics_dict()["tenants"]["a"]
    assert m["counters"]["batches"] == 1          # coalesced into one
    assert m["batch_occupancy"]["max"] == pytest.approx(10 / 16)


# ---------------------------------------------------------------------------
# update_regions validation (batched move indices)
# ---------------------------------------------------------------------------

def test_update_regions_rejects_out_of_range_indices():
    svc = DDMService(*paper_workload(seed=0, n_total=64, alpha=5.0))
    with pytest.raises(ValueError, match=r"outside \[0, 32\).*slot 1: "
                                         r"idx=40"):
        svc.update_regions("sub", [3, 40], [[0.0], [0.0]],
                           [[1.0], [1.0]])


def test_update_regions_rejects_negative_indices_instead_of_wrapping():
    svc = DDMService(*paper_workload(seed=0, n_total=64, alpha=5.0))
    before = svc.s_lo.copy()
    with pytest.raises(ValueError, match=r"slot 0: idx=-1"):
        svc.update_regions("sub", [-1], [[0.0]], [[1.0]])
    np.testing.assert_array_equal(svc.s_lo, before)   # nothing applied


def test_update_regions_rejects_non_integer_and_non_finite():
    svc = DDMService(*paper_workload(seed=0, n_total=64, alpha=5.0))
    with pytest.raises(ValueError, match="must be integers"):
        svc.update_regions("sub", [1.5], [[0.0]], [[1.0]])
    with pytest.raises(ValueError, match="non-finite"):
        svc.update_regions("sub", [1], [[np.nan]], [[1.0]])
    with pytest.raises(ValueError, match="kind must be"):
        svc.update_regions("pub", [1], [[0.0]], [[1.0]])


def test_update_regions_error_truncates_long_offender_list():
    svc = DDMService(*paper_workload(seed=0, n_total=64, alpha=5.0))
    bad = list(range(100, 110))
    with pytest.raises(ValueError, match=r"… 5 more"):
        svc.update_regions("sub", bad,
                           np.zeros((10, 1)), np.ones((10, 1)))


def test_valid_batch_still_applies_and_reports_deltas():
    S, U = paper_workload(seed=8, n_total=64, alpha=5.0)
    svc = DDMService(S, U)
    svc.connect()
    added, removed = svc.update_regions("sub", [2, 5], [[0.0], [10.0]],
                                        [[5.0], [20.0]])
    assert svc.version == 1
    assert all(s in (2, 5) for s, _ in added | removed)


def test_pad_moves_pow2_is_store_equivalent():
    idx = np.array([4, 9, 2], np.int64)
    lo = np.arange(3, dtype=np.float32).reshape(3, 1)
    hi = lo + 1
    pidx, plo, phi = pad_moves_pow2(idx, lo, hi)
    assert pidx.shape[0] == 4 and pidx[-1] == 2   # last entry repeated
    a = DDMService(*paper_workload(seed=0, n_total=64, alpha=5.0))
    b = DDMService(*paper_workload(seed=0, n_total=64, alpha=5.0))
    a.apply_moves("sub", idx, lo, hi)
    b.apply_moves("sub", pidx, plo, phi)
    np.testing.assert_array_equal(a.s_lo, b.s_lo)
    np.testing.assert_array_equal(a.s_hi, b.s_hi)


# ---------------------------------------------------------------------------
# satellites: rename stub, compilation cache, metrics schema
# ---------------------------------------------------------------------------

def test_lm_serve_rename_stub_warns_and_forwards():
    import importlib
    import repro.launch.lm_serve as lm
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        import repro.launch.serve as stub
        importlib.reload(stub)
    assert any(issubclass(x.category, DeprecationWarning)
               and "lm_serve" in str(x.message) for x in w)
    assert stub.main is lm.main


def test_compile_cache_enable_idempotent(tmp_path):
    import jax

    from repro.serve import compile_cache
    d = str(tmp_path / "jaxcache")
    got = compile_cache.enable(d)
    assert got == d
    assert jax.config.jax_compilation_cache_dir == d
    assert compile_cache.enable(d) == d     # idempotent
    assert compile_cache.enabled_dir() == d


def test_metrics_json_schema():
    server = _server()
    _add(server, "a")
    server.query("a", "sub", np.float32([0.0]), np.float32([1e5]))
    rec = server.metrics_dict()
    tm = rec["tenants"]["a"]
    assert set(tm) == {"counters", "gauges", "query_latency_us",
                       "batch_occupancy", "rebuild_lag_versions",
                       "rebuild_duration_us"}
    for field in ("count", "p50", "p99", "max", "mean"):
        assert field in tm["query_latency_us"]
    assert tm["counters"]["completed"] == 1
    # snapshot accounting gauges: set at registration, refreshed at
    # every rebuild publish
    assert set(tm["gauges"]) == {"snapshot_version", "snapshot_regions",
                                 "snapshot_bytes"}
    assert tm["gauges"]["snapshot_regions"] > 0
    assert tm["gauges"]["snapshot_bytes"] > 0
    # and it round-trips as JSON
    import json
    assert json.loads(server.metrics_json()) == rec


def test_unknown_tenant_and_target_errors():
    server = _server()
    _add(server, "a")
    with pytest.raises(ValueError, match="unknown tenant 'b'"):
        server.query("b", "sub", np.float32([0.0]), np.float32([1.0]))
    with pytest.raises(ValueError, match="target must be"):
        server.query("a", "all", np.float32([0.0]), np.float32([1.0]))
    with pytest.raises(ValueError, match="already registered"):
        _add(server, "a")
