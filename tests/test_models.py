"""Per-architecture smoke tests (reduced same-family configs, CPU).

For every assigned arch: one forward/loss, one grad step (finite,
non-zero), and prefill→decode consistency (decode with a KV/SSM cache
reproduces teacher-forced forward logits) — the correctness property the
serving path rests on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import transformer as T


def _batch(cfg, B=2, S=32, seed=1):
    tok = jax.random.randint(jax.random.PRNGKey(seed), (B, S + 1), 0,
                             cfg.vocab)
    batch = {"tokens": tok}
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    loss, metrics = jax.jit(lambda p, b: T.loss_fn(p, b, cfg))(
        params, _batch(cfg))
    assert np.isfinite(float(loss))
    assert float(metrics["ce"]) < 3 * np.log(cfg.vocab) + 5


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step_finite_and_nonzero(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    g = jax.jit(jax.grad(lambda p, b: T.loss_fn(p, b, cfg)[0]))(
        params, _batch(cfg))
    leaves = jax.tree.leaves(g)
    assert all(np.all(np.isfinite(np.asarray(l, np.float32)))
               for l in leaves), arch
    total = sum(float(jnp.sum(jnp.abs(l.astype(jnp.float32))))
                for l in leaves)
    assert total > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    """Teacher-forced forward logits == prefill+decode logits.

    This is the cache-correctness property, so decode must use the same
    numeric path as the forward it is compared against.  For MLA that
    means the expanded (non-absorbed) decode: the absorbed low-rank
    decode is mathematically identical but contracts ``q·(W_uk·ckv)`` as
    ``(q·W_uk)·ckv`` in f32, skipping the bf16 rounding of ``k_nope``
    that the forward path applies — a ~5e-2 logit drift that is
    accumulation-order noise, not a cache bug.  The absorbed path's
    drift is bounded separately in
    ``test_mla_absorbed_decode_matches_expanded``.
    """
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.mla:
        cfg = dataclasses.replace(cfg, mla_absorb=False)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = _batch(cfg, B=B, S=S)
    tokens = batch["tokens"][:, :-1]
    frames = batch.get("frames")

    full_logits, _, _ = jax.jit(
        lambda p, t, f: T.forward(p, t, cfg, frames=f))(
        params, tokens, frames)

    max_len = S + 8
    cache = T.init_cache(cfg, B, max_len)
    n_pre = S // 2
    _, cache = jax.jit(
        lambda p, t, c, f: T.prefill(p, t, cfg, c, frames=f))(
        params, tokens[:, :n_pre], cache, frames)
    outs = []
    step = jax.jit(
        lambda p, t, c, i: T.decode_step(p, t, cfg, c, i))
    for i in range(n_pre, S):
        logits, cache = step(params, tokens[:, i:i + 1], cache,
                             jnp.int32(i))
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)            # (B, S-n_pre, vocab)
    want = full_logits[:, n_pre:]
    np.testing.assert_allclose(np.asarray(dec), np.asarray(want),
                               rtol=5e-2, atol=5e-2)


def test_mla_absorbed_decode_matches_expanded():
    """Absorbed (W_uk/W_uv folded) decode == expanded decode, within the
    rounding drift of the absorption trick.

    The two paths are algebraically identical; they differ only in where
    bf16 rounding lands (expanded rounds ``k_nope``/``v`` per element,
    absorbed keeps the low-rank contraction in f32).  Measured drift is
    ~5.3e-2 max on smoke-sized logits across seeds; the bound below is
    ~2x that.  A genuine cache or masking bug produces O(1) logit errors
    and still fails this.
    """
    import dataclasses
    cfg_e = dataclasses.replace(get_smoke_config("deepseek_v2_236b"),
                                mla_absorb=False)
    cfg_a = dataclasses.replace(cfg_e, mla_absorb=True)
    assert cfg_e.mla
    params = T.init_params(cfg_e, jax.random.PRNGKey(0))
    B, S = 2, 24
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg_e.vocab)
    n_pre = S // 2
    outs = {}
    for name, cfg in (("expanded", cfg_e), ("absorbed", cfg_a)):
        cache = T.init_cache(cfg, B, S + 4)
        _, cache = T.prefill(params, tok[:, :n_pre], cfg, cache)
        logits = []
        for i in range(n_pre, S):
            lg, cache = T.decode_step(params, tok[:, i:i + 1], cfg,
                                      cache, jnp.int32(i))
            logits.append(np.asarray(lg))
        outs[name] = np.stack(logits)
    np.testing.assert_allclose(outs["absorbed"], outs["expanded"],
                               rtol=0.1, atol=0.1)


def test_window_decode_equals_full_when_window_covers():
    """DDM-window read == full attention when window >= context."""
    import dataclasses
    cfg = get_smoke_config("zamba2_2_7b")
    cfg_full = dataclasses.replace(cfg, attn_pattern="full")
    assert cfg.window >= 64
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 20
    tok = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    la, _, _ = T.forward(params, tok, cfg)
    lb, _, _ = T.forward(params, tok, cfg_full)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=1e-4, atol=1e-4)


def test_moe_aux_loss_positive_and_capacity_drops():
    cfg = get_smoke_config("phi3_5_moe_42b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    _, metrics = T.loss_fn(params, _batch(cfg), cfg)
    assert float(metrics["aux"]) > 0.5  # ~1.0 at uniform routing


def test_param_count_analytic_close_to_actual():
    """config.n_params() ~ actual init sizes (sanity for rooflines)."""
    for arch in ("llama3_2_3b", "mamba2_780m", "phi3_5_moe_42b"):
        cfg = get_smoke_config(arch)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree.leaves(params))
        predicted = cfg.n_params()
        assert abs(actual - predicted) / actual < 0.15, \
            (arch, actual, predicted)


def test_window_gather_decode_equals_masked_decode():
    """Beyond-paper §Perf lever: gather-decode (reads only the DDM
    window + sink) must be numerically identical to the masked
    full-context read."""
    import dataclasses
    cfg_m = dataclasses.replace(get_smoke_config("zamba2_2_7b"),
                                window=24, n_sink_blocks=1, block_kv=8)
    cfg_g = dataclasses.replace(cfg_m, window_gather_decode=True)
    params = T.init_params(cfg_m, jax.random.PRNGKey(0))
    B, S = 2, 40
    tok = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                             cfg_m.vocab)
    outs = {}
    for name, cfg in (("masked", cfg_m), ("gather", cfg_g)):
        cache = T.init_cache(cfg, B, S + 4)
        _, cache = T.prefill(params, tok[:, :20], cfg, cache)
        logits = []
        for i in range(20, S):
            lg, cache = T.decode_step(params, tok[:, i:i + 1], cfg,
                                      cache, jnp.int32(i))
            logits.append(np.asarray(lg))
        outs[name] = np.stack(logits)
    np.testing.assert_allclose(outs["gather"], outs["masked"],
                               rtol=2e-2, atol=2e-2)
